"""Stage registry: the behavioural half of the stage-polymorphic node model.

``ragraph.py`` keeps nodes as plain frozen data tagged with a ``kind``
string; everything a scheduler layer needs to *do* with a stage lives here,
behind one ``StageSpec`` per kind:

* entry/completion — ``enter`` (re)initialises per-request progress when a
  request sits at a fresh node (instant completions loop in the caller),
  ``write_output`` folds the finished stage's result into request state;
* splitting — ``unit_cost_us`` + the generic ``assemble`` drive
  ``transforms.split_stage_next`` under ``TimeBudget.units_for_budget``
  (Eq. 1 applied to any splittable unit queue: IVF clusters, candidate
  blocks, query variants);
* cost profile — ``min_service_us`` feeds the admission controller's
  isolated-service lower bound and ``remaining_us`` the SLO-slack
  estimator (``serving/dispatch.py``), so new stage kinds are admission-
  and slack-aware without touching either;
* cross-request fusion — ``fusion_signature`` produces the
  (key, bucket, unit-vec) triple ``crossreq/dedup.py`` matches on, so
  rerank/rewrite stages dedup across requests exactly like retrieval;
* speculation capabilities — class flags (``emits_partial_queries``,
  ``accepts_probe_warmup``, ``supports_spec_start``) replace the scheduler's
  old hard-wired kind checks.

The scheduler (``core/wavefront.py``) dispatches exclusively through
``spec_for(node)`` / ``spec(kind)``; registering a new kind via
``register_stage`` is all it takes to plug a stage type into splitting,
slack ordering, admission control, dedup/fusion and the serving loop.

Built-in kinds: ``generation`` and ``retrieval`` (the paper's Listing 1
pair — their spec bodies are verbatim moves of the pre-registry scheduler
branches, pinned bit-identical by ``tests/golden_fingerprints.json``), plus
``rerank`` (cross-encoder candidate scoring), ``rewrite`` (multi-query
expansion with BatchTopK k-way merge) and ``compress`` (extractive
block-saliency compression).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np

from repro.core import similarity, transforms
from repro.core.ragraph import (CompressNode, GenerationNode, RerankNode,
                                RetrievalNode, RewriteNode)
from repro.core.runtime import GenProgress, RetProgress, StageProgress
from repro.core.similarity import LocalCache
from repro.retrieval.ivf import TopK
from repro.retrieval.plan import BatchTopK

# resource classes: which worker pool executes the stage
GEN = "gen"  # the accelerator-side generation worker
HOST = "ret"  # the host-side retrieval worker pool


# ---------------------------------------------------------------------------
# Cross-layer value types
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FusionSig:
    """What the in-flight dedup/fusion pass matches on.  ``key`` is the
    exact byte-hash identity (stage kind + query payload + knobs);
    ``bucket`` partitions near-match comparisons (kind + result-shape knobs,
    so fused answers keep the subscriber's k/nprobe); ``unit_vec`` is the
    normalised query for cosine near-matching, or None for exact-only
    stages (rerank/compress, whose results are candidate-set specific)."""

    key: bytes
    bucket: tuple
    unit_vec: Optional[np.ndarray] = None


@dataclasses.dataclass
class CostCtx:
    """Cost-model context handed to ``remaining_us`` by the slack/admission
    estimators (serving/dispatch.py)."""

    budget: Any  # core.substage.TimeBudget
    cost_model: Any  # retrieval.ivf.ClusterCostModel
    sizes: Any  # per-cluster vector counts
    shard_map: Any = None
    merge_us: float = 0.0


@dataclasses.dataclass
class StageTask:
    """One dispatched batch of generic host-stage work units (the host-task
    analogue of a retrieval plan group).  ``execute`` is the deferred pure
    compute; backends charge ``cost_us`` (sim) or the measured wall time
    (real) via ``stage_charged``."""

    kind: str
    req: Any  # runtime.RequestContext
    units: list
    cost_us: float
    fanout: int  # fused-group width at dispatch time (charge once)
    execute: Callable[[], Any]
    sn: Any = None  # runtime-DAG sub-node covering the batch


@dataclasses.dataclass(frozen=True)
class StageCostProfile:
    fixed_us: float  # per-dispatched-batch overhead
    unit_us: float  # per elementary work item (candidate doc, ...)


# ---------------------------------------------------------------------------
# The spec protocol
# ---------------------------------------------------------------------------


class StageSpec:
    """Behaviour of one stage kind.  Subclasses override the hooks their
    resource class needs; the base provides inert defaults so a minimal new
    stage only implements ``enter``/``write_output``/``min_service_us``."""

    kind: str = ""
    resource: str = HOST
    splittable: bool = False
    # speculation capabilities (paper §4.3) — replace hard-wired kind checks
    emits_partial_queries: bool = False  # gen->ret: partial output embeds
    accepts_probe_warmup: bool = False  # ret-side LocalCache warmups apply
    supports_spec_start: bool = False  # ret->gen: may pre-start this stage

    # ------------------------------------------------------- declared wiring
    def inputs(self, node) -> list:
        return node.inputs()

    def outputs(self, node) -> list:
        return [node.output]

    # --------------------------------------------------------- stage entry
    def probe_hint_nprobe(self, node, cfg) -> Optional[int]:
        """nprobe for the batched arrival-time probe_order prefetch, or None
        when the stage does not consume a probe hint."""
        return None

    def enter(self, sched, req, now) -> bool:
        """(Re)initialise progress at a fresh node.  Returns True when the
        stage completed instantly (the scheduler loops to the next node)."""
        raise NotImplementedError

    # --------------------------------------------------------- cost profile
    def min_service_us(self, adm) -> float:
        """Isolated-service lower bound per node of this kind (admission
        control; ``adm`` is the AdmissionController)."""
        raise NotImplementedError

    def remaining_us(self, req, prog, ctx: CostCtx) -> float:
        """First-order remaining-service estimate for an active progress
        record (SLO-slack ordering)."""
        return 0.0

    # ------------------------------------------------- cross-request fusion
    def fusion_fresh(self, req) -> bool:
        """True while the stage has not executed any work yet (only fresh
        stages may subscribe to, or lead, a fused group)."""
        return False

    def fusion_signature(self, sched, req) -> Optional[FusionSig]:
        return None

    def park_subscriber(self, sched, req) -> None:
        raise NotImplementedError

    def adopt_from_leader(self, sched, sub, leader, match, now) -> None:
        raise NotImplementedError

    # -------------------------------------------------------- host assembly
    def assemble(self, sched, req, builders, tasks, cycle_load, idle, now,
                 *, whole_stage: bool) -> None:
        """Split off the next sub-stage under the time budget and dispatch
        it to the worker pool (plan groups and/or StageTasks)."""
        raise NotImplementedError

    def complete_plan_group(self, sched, req, ref, res, g, kg, now) -> None:
        """A plan group dispatched by ``assemble`` landed (meta tag
        ``("stage", req, spec, ref)``)."""
        raise NotImplementedError

    def complete_task(self, sched, task: StageTask, result, now) -> None:
        """A StageTask dispatched by ``assemble`` landed."""
        raise NotImplementedError

    # ----------------------------------------------------------- completion
    def write_output(self, sched, req, now) -> None:
        """Fold the finished stage's result into ``req.state``."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

STAGE_REGISTRY: dict[str, StageSpec] = {}


def register_stage(spec: StageSpec) -> StageSpec:
    if not spec.kind:
        raise ValueError("stage spec must declare a kind")
    STAGE_REGISTRY[spec.kind] = spec
    return spec


def spec(kind: str) -> StageSpec:
    try:
        return STAGE_REGISTRY[kind]
    except KeyError:
        raise KeyError(
            f"no StageSpec registered for kind {kind!r}; known kinds: "
            f"{sorted(STAGE_REGISTRY)} — register one via "
            f"repro.core.stages.register_stage") from None


def spec_for(node) -> StageSpec:
    return spec(node.kind)


def active_progress(req) -> list:
    """(progress, kind) pairs for every unfinished stage progress a request
    carries — the iteration order (ret, gen, stage) matches the legacy
    slack estimator so summation order (and float results) are unchanged."""
    out = []
    if req.ret is not None and not req.ret.done:
        out.append((req.ret, "retrieval"))
    if req.gen is not None and not req.gen.done:
        out.append((req.gen, "generation"))
    st = req.stage
    if st is not None and not st.done:
        out.append((st, st.kind))
    return out


# ---------------------------------------------------------------------------
# Generation
# ---------------------------------------------------------------------------


class GenerationSpec(StageSpec):
    kind = "generation"
    resource = GEN
    splittable = True  # by decode steps (continuous batching)
    emits_partial_queries = True
    supports_spec_start = True

    def enter(self, sched, req, now) -> bool:
        node = req.node
        if req.gen is None:
            tgt = sched.workload.gen_tokens(req.request_id, node.node_id,
                                            node.max_tokens)
            req.gen = GenProgress(target_tokens=tgt, started_at=now,
                                  node_id=node.node_id)
            req.log(now, "gen_stage_start", node.node_id)
        return False

    def min_service_us(self, adm) -> float:
        # at least one decode step at the current EMA step cost
        return adm.budget.t_decode_step_us

    def remaining_us(self, req, prog, ctx: CostCtx) -> float:
        remaining = max(prog.target_tokens - prog.generated, 0)
        return remaining * ctx.budget.t_decode_step_us


# ---------------------------------------------------------------------------
# Retrieval
# ---------------------------------------------------------------------------


class RetrievalSpec(StageSpec):
    kind = "retrieval"
    resource = HOST
    splittable = True  # by IVF cluster
    accepts_probe_warmup = True

    def probe_hint_nprobe(self, node, cfg) -> Optional[int]:
        return node.nprobe or cfg.nprobe

    def enter(self, sched, req, now) -> bool:
        node = req.node
        if req.ret is not None:
            return False
        nprobe = node.nprobe or sched.cfg.nprobe
        hint = sched._probe_hints.pop(req.request_id, None)
        if hint is not None:
            qv, queue = hint
            queue = list(queue)
        else:
            qv = sched.backend.query_embedding(req, req.round_idx)
            queue = [int(c) for c in
                     sched.index.probe_order(qv[None], nprobe)[0]]
        req.ret = RetProgress(
            query_vec=qv, cluster_queue=queue,
            topk=TopK.empty(node.topk or sched.cfg.topk),
            k=node.topk or sched.cfg.topk, nprobe=nprobe, started_at=now,
        )
        if req.sim_cache is None:
            req.sim_cache = LocalCache()
        req.log(now, "ret_stage_start", node.node_id)
        if sched.cfg.enable_reorder or sched.cfg.enable_cache_answer:
            rep = transforms.reorder_retrieval(req)
            if rep["reordered"]:
                sched.metrics.reorders += 1
            if rep["cache_answer"] and sched.cfg.enable_cache_answer:
                sched.metrics.cache_answers += 1
                sched._finish_ret_stage(req, now)
                return True  # advanced; maybe next stage is instant too
            if rep["cache_answer"]:
                # cache answers disabled: restore full queue
                req.ret.answered_from_cache = False
        # cross-request semantic cache: conclusive answer (exact-key
        # or O1 ball bound), else inherit the nearest hot entry's
        # H_v/C_v when this request has no local history of its own
        if (sched.crossreq is not None
                and sched.crossreq.global_cache is not None
                and not req.ret.done):
            ans, ent = sched.crossreq.global_cache.consult(
                req.ret.query_vec, req.ret.k, req.ret.nprobe,
                allow_answer=sched.cfg.enable_cache_answer,
                allow_seed=sched.cfg.enable_reorder and (
                    req.sim_cache is None or req.sim_cache.empty))
            if ans is not None:
                req.ret.topk = req.ret.topk.merge(*ans)
                req.ret.answered_from_cache = True
                req.ret.cluster_queue = []
                sched.metrics.global_cache_answers += 1
                sched._finish_ret_stage(req, now)
                return True  # advanced; maybe next stage is instant too
            if ent is not None:
                seeded = similarity.reorder_clusters(
                    req.ret.cluster_queue, ent)
                req.ret.cluster_queue = seeded.order
                sched.metrics.global_cache_seeds += 1
        if not sched.cfg.mode == "hedra":
            sched._ret_fifo.append(req)
        return False

    def min_service_us(self, adm) -> float:
        # one smallest-cluster scan; in shard mode sharding cannot shrink a
        # single smallest-cluster scan (max over one shard == that shard)
        # but every stage additionally pays one scatter-gather merge
        return adm.cost_model.cost_us(adm.min_cluster_size) + adm.merge_us

    def remaining_us(self, req, prog, ctx: CostCtx) -> float:
        if not prog.cluster_queue:
            return 0.0
        queued = np.asarray(prog.cluster_queue, np.int64)
        if ctx.shard_map is None:
            return ctx.cost_model.batch_cost_us(ctx.sizes[queued])
        from repro.serving.dispatch import sharded_scan_cost_us
        return sharded_scan_cost_us(queued, ctx.cost_model, ctx.sizes,
                                    ctx.shard_map, ctx.merge_us)

    # ------------------------------------------------------------ fusion
    def fusion_fresh(self, req) -> bool:
        return not req.ret.searched

    def fusion_signature(self, sched, req) -> FusionSig:
        r = req.ret
        key = (b"retrieval|"
               + np.asarray(r.query_vec, np.float32).tobytes()
               + np.array([r.k, r.nprobe], np.int64).tobytes())
        q = np.asarray(r.query_vec, np.float64)
        unit = q / max(float(np.linalg.norm(q)), 1e-12)
        return FusionSig(key, ("retrieval", r.k, r.nprobe), unit)

    def park_subscriber(self, sched, req) -> None:
        req.ret._inflight = True  # type: ignore[attr-defined]

    # (retrieval fan-out lives in the scheduler's _crossreq_stage_done —
    # it predates the registry and carries the LocalCache soundness logic)

    # -------------------------------------------------------- completion
    def write_output(self, sched, req, now) -> None:
        node = req.node
        ids = req.ret.topk.ids
        out = [int(i) for i in ids if i >= 0]
        if getattr(node, "lexical_weight", 0.0) > 0.0 and out:
            # dense+lexical hybrid fusion: rescore the stage's final dense
            # top-k with the backend's lexical (term-match) scorer and fold
            # via weighted reciprocal-rank fusion — an instant transform at
            # stage completion, like reorders.  lexical_weight == 0 keeps
            # the pure dense path bit-identical to the pre-hybrid behaviour.
            from repro.retrieval.lexical import rrf_fuse
            text = req.state.get(node.query, req.state.get("input", ""))
            if isinstance(text, dict):
                text = text.get("text", "")
            lex = sched.backend.lexical_scores(str(text), out)
            out = rrf_fuse(out, lex, node.lexical_weight)
            sched.metrics.lexical_fusions += 1
            req.log(now, "lexical_fused", node.node_id)
        req.state[node.output] = out
        # stash the stage's query embedding for downstream rerank/compress
        # anchoring (state keys are runtime-internal, invisible to the
        # event fingerprint and the journal)
        req.state[f"_qv_{node.output}"] = req.ret.query_vec


# ---------------------------------------------------------------------------
# Generic host stages (rerank / compress / rewrite share the machinery)
# ---------------------------------------------------------------------------


class HostStageSpec(StageSpec):
    """Shared machinery for registry host stages executed as generic work-
    unit queues (StageProgress): budgeted splitting via
    ``transforms.split_stage_next``, dispatch through the same worker pool /
    dispatcher as retrieval, exact-key cross-request fusion."""

    resource = HOST
    splittable = True
    profile = StageCostProfile(fixed_us=0.0, unit_us=0.0)

    # ------------------------------------------------------ subclass hooks
    def open_progress(self, sched, req, now) -> StageProgress:
        raise NotImplementedError

    def unit_cost_us(self, sched, req, unit) -> float:
        n = len(unit) if isinstance(unit, (list, tuple)) else 1
        return self.profile.unit_us * n

    def make_execute(self, sched, req, units) -> Callable[[], Any]:
        raise NotImplementedError

    def fold(self, sched, req, result) -> None:
        """Fold a completed batch's result into the stage payload."""
        raise NotImplementedError

    def on_adopt(self, sched, sub, leader) -> None:
        """Extra subscriber-side state on fused adoption (optional)."""

    # ------------------------------------------------------------- entry
    def enter(self, sched, req, now) -> bool:
        node = req.node
        if req.stage is not None:
            return False
        req.stage = prog = self.open_progress(sched, req, now)
        prog.started_at = now
        req.log(now, f"{self.kind}_stage_start", node.node_id)
        if prog.done:
            # degenerate stage (no candidates): completes instantly
            sched._finish_stage(req, now)
            return True
        if not sched.cfg.mode == "hedra":
            sched._ret_fifo.append(req)
        return False

    # ---------------------------------------------------------- assembly
    def assemble(self, sched, req, builders, tasks, cycle_load, idle, now,
                 *, whole_stage: bool) -> None:
        prog = req.stage
        costs = (None if whole_stage else
                 [self.unit_cost_us(sched, req, u) for u in prog.work_queue])
        sn = transforms.split_stage_next(sched.dag, req, sched.budget, costs,
                                         whole_stage=whole_stage)
        if sn is None:
            return
        units = sn.payload["units"]
        prog.work_queue = prog.work_queue[len(units):]
        prog.inflight_units += len(units)
        self.dispatch_units(sched, req, units, sn, builders, tasks,
                            cycle_load, idle, now)

    def dispatch_units(self, sched, req, units, sn, builders, tasks,
                       cycle_load, idle, now) -> None:
        """Default dispatch: one StageTask on a policy-picked worker, with
        candidate-doc cluster ownership as the affinity signal."""
        flat = [int(d) for blk in units for d in blk]
        aff = (sched.index.doc_cluster(np.asarray(flat, np.int64))
               if flat else np.zeros(0, np.int64))
        wid = sched.dispatcher.pick_worker([int(c) for c in aff], idle,
                                           extra_load=cycle_load)
        cost = self.profile.fixed_us + sum(
            self.unit_cost_us(sched, req, u) for u in units)
        fanout = 1
        if sched.crossreq is not None and sched.crossreq.fusion is not None:
            fanout = sched.crossreq.fusion.fanout(req.request_id)
        task = StageTask(self.kind, req, list(units), float(cost), fanout,
                         self.make_execute(sched, req, units), sn)
        tasks[wid].append(task)
        sched.dispatcher.note_dispatch(wid, [int(c) for c in aff])
        cycle_load[wid] = cycle_load.get(wid, 0.0) + float(cost)
        sched.metrics.stage_tasks += 1

    # -------------------------------------------------------- completion
    def complete_task(self, sched, task: StageTask, result, now) -> None:
        req = task.req
        if task.sn is not None:
            sched.dag.complete(task.sn)
        prog = req.stage
        if req.finished or prog is None or prog.kind != self.kind:
            return
        self.fold(sched, req, result)
        prog.inflight_units -= len(task.units)
        if prog.done:
            sched._finish_stage(req, now)

    # ------------------------------------------------------------ fusion
    def fusion_fresh(self, req) -> bool:
        prog = req.stage
        return (not prog.parked and prog.inflight_units == 0
                and len(prog.work_queue) == prog.total_units)

    def park_subscriber(self, sched, req) -> None:
        req.stage.parked = True

    def adopt_from_leader(self, sched, sub, leader, match, now) -> None:
        node = sub.node
        prog = sub.stage
        prog.parked = False
        prog.work_queue = []
        prog.inflight_units = 0
        sub.state[node.output] = list(leader.state[leader.node.output])
        self.on_adopt(sched, sub, leader)
        sub.log(now, f"{self.kind}_stage_done", node.node_id)
        sched._advance_request(sub, now)

    # --------------------------------------------------------------- util
    def _anchor_vec(self, sched, req, docs_key) -> np.ndarray:
        """Query embedding anchoring the scoring: the producing retrieval/
        rewrite stage's stashed vector, else a fresh embed of the request."""
        qv = req.state.get(f"_qv_{docs_key}")
        if qv is None:
            qv = sched.backend.query_embedding(req, req.round_idx)
        return np.asarray(qv, np.float32)

    def _block_progress(self, sched, req, docs_key, block) -> StageProgress:
        cand = [int(i) for i in req.state.get(docs_key, [])]
        qv = self._anchor_vec(sched, req, docs_key)
        blocks = [cand[i:i + block] for i in range(0, len(cand), block)]
        return StageProgress(
            kind=self.kind, work_queue=blocks, total_units=len(blocks),
            payload={"qv": qv, "scores": {}, "n_cand": len(cand)})

    def _exact_sig(self, req, docs_key, *params) -> FusionSig:
        prog = req.stage
        qv = np.asarray(prog.payload["qv"], np.float32)
        cand = [int(i) for i in req.state.get(docs_key, [])]
        key = (f"{self.kind}|".encode()
               + qv.tobytes()
               + np.array(list(params) + cand, np.int64).tobytes())
        return FusionSig(key, (self.kind,) + tuple(params), None)


# ---------------------------------------------------------------------------
# Rerank
# ---------------------------------------------------------------------------


def cross_encoder_scores(index, qv: np.ndarray, doc_ids) -> dict:
    """Synthetic cross-encoder: a nonlinear query-document interaction model
    (saturating per-dimension interaction map + global match), deliberately
    *not* monotone in L2 distance so reranking genuinely permutes the dense
    order.  Pure and deterministic; both backends execute the same math
    (sim defers it behind a modelled charge, real times it)."""
    if not len(doc_ids):
        return {}
    D = index.doc_vectors(doc_ids)
    q = np.asarray(qv, np.float32)
    inter = np.tanh(D * q[None, :]).sum(-1)  # per-dim interaction features
    match = np.tanh(D @ q)  # global semantic match
    score = match + 0.5 * inter
    return {int(d): float(s) for d, s in zip(doc_ids, score)}


class RerankSpec(HostStageSpec):
    kind = "rerank"
    # cross-encoder pair scoring is expensive relative to an IVF scan probe:
    # ~60us per (query, doc) pair in the modelled host cost
    profile = StageCostProfile(fixed_us=250.0, unit_us=60.0)

    def open_progress(self, sched, req, now) -> StageProgress:
        return self._block_progress(sched, req, req.node.docs, req.node.block)

    def make_execute(self, sched, req, units):
        qv = req.stage.payload["qv"]
        ids = [int(d) for blk in units for d in blk]
        index = sched.index

        def execute():
            return cross_encoder_scores(index, qv, ids)

        return execute

    def fold(self, sched, req, result) -> None:
        req.stage.payload["scores"].update(result)

    def fusion_signature(self, sched, req) -> FusionSig:
        return self._exact_sig(req, req.node.docs, req.node.keep)

    def min_service_us(self, adm) -> float:
        return self.profile.fixed_us + self.profile.unit_us

    def remaining_us(self, req, prog, ctx: CostCtx) -> float:
        n = sum(len(b) for b in prog.work_queue)
        return self.profile.fixed_us + self.profile.unit_us * n if n else 0.0

    def write_output(self, sched, req, now) -> None:
        node = req.node
        scores = req.stage.payload["scores"]
        order = sorted(scores.items(), key=lambda kv: (-kv[1], kv[0]))
        req.state[node.output] = [int(d) for d, _ in order[:node.keep]]
        req.state[f"_qv_{node.output}"] = req.stage.payload["qv"]


# ---------------------------------------------------------------------------
# Compress
# ---------------------------------------------------------------------------


def compression_scores(index, qv: np.ndarray, doc_ids, block: int) -> dict:
    """Extractive-compression saliency: training/compression.py's per-block
    absmax scale rule as the information-density proxy, crossed with query
    affinity so kept context is both dense and on-topic."""
    if not len(doc_ids):
        return {}
    from repro.training.compression import block_saliency

    D = index.doc_vectors(doc_ids)
    q = np.asarray(qv, np.float32)
    sal = block_saliency(D, block)
    affinity = 1.0 / (1.0 + np.sqrt(((D - q[None, :]) ** 2).sum(-1)))
    score = sal * affinity
    return {int(d): float(s) for d, s in zip(doc_ids, score)}


class CompressSpec(HostStageSpec):
    kind = "compress"
    profile = StageCostProfile(fixed_us=150.0, unit_us=25.0)

    def open_progress(self, sched, req, now) -> StageProgress:
        return self._block_progress(sched, req, req.node.docs, req.node.block)

    def make_execute(self, sched, req, units):
        qv = req.stage.payload["qv"]
        ids = [int(d) for blk in units for d in blk]
        index = sched.index
        block = req.node.block

        def execute():
            return compression_scores(index, qv, ids, block)

        return execute

    def fold(self, sched, req, result) -> None:
        req.stage.payload["scores"].update(result)

    def fusion_signature(self, sched, req) -> FusionSig:
        ratio_pm = int(round(req.node.ratio * 1_000_000))
        return self._exact_sig(req, req.node.docs, ratio_pm)

    def min_service_us(self, adm) -> float:
        return self.profile.fixed_us + self.profile.unit_us

    def remaining_us(self, req, prog, ctx: CostCtx) -> float:
        n = sum(len(b) for b in prog.work_queue)
        return self.profile.fixed_us + self.profile.unit_us * n if n else 0.0

    def write_output(self, sched, req, now) -> None:
        node = req.node
        pl = req.stage.payload
        keep = max(1, int(round(pl["n_cand"] * node.ratio)))
        order = sorted(pl["scores"].items(), key=lambda kv: (-kv[1], kv[0]))
        req.state[node.output] = [int(d) for d, _ in order[:keep]]
        req.state[f"_qv_{node.output}"] = pl["qv"]


# ---------------------------------------------------------------------------
# Rewrite (multi-query expansion)
# ---------------------------------------------------------------------------


class RewriteSpec(HostStageSpec):
    kind = "rewrite"

    def open_progress(self, sched, req, now) -> StageProgress:
        node = req.node
        base = np.asarray(
            sched.backend.query_embedding(req, req.round_idx), np.float32)
        nprobe = node.nprobe or sched.cfg.nprobe
        k = node.topk or sched.cfg.topk
        n = max(1, int(node.n_queries))
        d = base.shape[0]
        # deterministic query expansion: variant 0 is the base query, the
        # rest add seeded isotropic noise scaled to ~25% of the query norm
        scale = 0.25 * float(np.linalg.norm(base)) / max(float(np.sqrt(d)), 1.0)
        variants = [base]
        for i in range(1, n):
            rng = np.random.default_rng(
                np.random.SeedSequence([1009, req.request_id, req.round_idx, i]))
            v = base + scale * rng.standard_normal(d).astype(np.float32)
            variants.append(np.asarray(v, np.float32))
        probes = sched.index.probe_order(np.stack(variants), nprobe)
        return StageProgress(
            kind=self.kind, work_queue=list(range(n)), total_units=n,
            payload={
                "base": base, "k": k, "nprobe": nprobe,
                "variants": variants,
                "probes": [[int(c) for c in row] for row in probes],
                # the k-way merge board: one row per variant, folded through
                # the shared BatchTopK merge at stage completion
                "board": BatchTopK.empty(n, k),
                "sn_pending": {},
            })

    def unit_cost_us(self, sched, req, unit) -> float:
        probes = req.stage.payload["probes"][unit]
        return float(sched.backend.cluster_cost_model.batch_cost_us(
            sched._cluster_sizes[np.asarray(probes, np.int64)]))

    def dispatch_units(self, sched, req, units, sn, builders, tasks,
                       cycle_load, idle, now) -> None:
        """Variant scans are real IVF work: dispatch one plan group per
        variant through the same PlanBuilder path as retrieval sub-stages
        (affinity placement, popularity feed, fused-group charging)."""
        prog = req.stage
        pl = prog.payload
        cm = sched.backend.cluster_cost_model
        fanout = 1
        if sched.crossreq is not None and sched.crossreq.fusion is not None:
            fanout = sched.crossreq.fusion.fanout(req.request_id)
        pl["sn_pending"][sn.sid] = [sn, len(units)]
        for vi in units:
            probes = pl["probes"][vi]
            wid = sched.dispatcher.pick_worker(probes, idle,
                                               extra_load=cycle_load)
            builders[wid].add(pl["variants"][vi], probes, k=pl["k"],
                              meta=("stage", req, self, (int(vi), sn.sid)),
                              fanout=fanout)
            sched.dispatcher.note_dispatch(wid, probes)
            cycle_load[wid] = cycle_load.get(wid, 0.0) + float(
                cm.batch_cost_us(
                    sched._cluster_sizes[np.asarray(probes, np.int64)]))
        sched.metrics.stage_tasks += len(units)

    def complete_plan_group(self, sched, req, ref, res, g, kg, now) -> None:
        vi, sid = ref
        prog = req.stage
        if req.finished or prog is None or prog.kind != self.kind:
            return
        pl = prog.payload
        row = res.group_topk(g, kg)
        pl["board"].merge_rows(np.array([vi], np.int64),
                               row.dists[None], row.ids[None])
        pending = pl["sn_pending"].get(sid)
        if pending is not None:
            pending[1] -= 1
            if pending[1] <= 0:
                sched.dag.complete(pending[0])
                del pl["sn_pending"][sid]
        prog.inflight_units -= 1
        if prog.done:
            sched._finish_stage(req, now)

    def min_service_us(self, adm) -> float:
        # one variant = at least one smallest-cluster scan (+ shard merge)
        return adm.cost_model.cost_us(adm.min_cluster_size) + adm.merge_us

    def remaining_us(self, req, prog, ctx: CostCtx) -> float:
        est = 0.0
        for vi in prog.work_queue:
            probes = np.asarray(prog.payload["probes"][vi], np.int64)
            est += ctx.cost_model.batch_cost_us(ctx.sizes[probes])
        return est

    def fusion_signature(self, sched, req) -> FusionSig:
        pl = req.stage.payload
        base = np.asarray(pl["base"], np.float32)
        node = req.node
        params = (pl["k"], int(node.n_queries), pl["nprobe"])
        key = (b"rewrite|" + base.tobytes()
               + np.array(params, np.int64).tobytes())
        q = np.asarray(base, np.float64)
        unit = q / max(float(np.linalg.norm(q)), 1e-12)
        return FusionSig(key, ("rewrite",) + params, unit)

    def on_adopt(self, sched, sub, leader) -> None:
        sub.state[f"_qv_{sub.node.output}"] = sub.stage.payload["base"]
        sub.round_idx += 1

    def write_output(self, sched, req, now) -> None:
        node = req.node
        pl = req.stage.payload
        board = pl["board"]
        k = pl["k"]
        # k-way merge of the per-variant top-k rows through the shared
        # BatchTopK fold, then first-occurrence doc-id dedup in ascending
        # distance order (a doc found by several variants counts once)
        fold = BatchTopK.empty(1, board.n * k)
        fold.merge_rows(np.zeros(1, np.int64),
                        board.dists.reshape(1, -1),
                        board.ids.reshape(1, -1))
        seen: set = set()
        out: list = []
        for doc in fold.ids[0]:
            doc = int(doc)
            if doc < 0 or doc in seen:
                continue
            seen.add(doc)
            out.append(doc)
            if len(out) >= k:
                break
        req.state[node.output] = out
        req.state[f"_qv_{node.output}"] = pl["base"]
        # the expansion consumed this round's query embedding
        req.round_idx += 1


register_stage(GenerationSpec())
register_stage(RetrievalSpec())
register_stage(RerankSpec())
register_stage(RewriteSpec())
register_stage(CompressSpec())
