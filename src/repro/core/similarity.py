"""Similarity-aware search optimization (paper §4.3, C4).

Implements the three locality-based observations and the machinery that
exploits them:

  O1  results of v' are often inside the *larger top-k'* results of v
      -> keep a per-request local cache of k'=20 results; answer v' from the
         cache when it is conclusive;
  O2  results of v' tend to live in H_v (clusters that held v's results)
      -> search H_v ∩ C' first;
  O3  results of v' tend to live in C_v ∩ C' (clusters probed for v)
      -> search (C_v - H_v) ∩ C' second, the rest last.

Cluster reordering feeds the triangle-inequality early-termination check in
the scheduler: once the running kth distance is below the lossless lower
bound of every remaining cluster, the stage stops early (paper Fig. 9b).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.retrieval.ivf import IVFIndex, TopK


@dataclasses.dataclass
class LocalCache:
    """Per-request history of the previous retrieval stage."""

    k_prime: int = 20
    query_vec: Optional[np.ndarray] = None
    dists: Optional[np.ndarray] = None  # (k',) of previous search
    ids: Optional[np.ndarray] = None  # (k',)
    home_clusters: Optional[set] = None  # H_v
    probed_clusters: Optional[set] = None  # C_v

    def update(self, query_vec: np.ndarray, topk: TopK, index: IVFIndex,
               probed: list[int]) -> None:
        self.query_vec = np.asarray(query_vec, np.float32)
        self.dists = topk.dists.copy()
        self.ids = topk.ids.copy()
        valid = topk.ids[topk.ids >= 0]
        self.home_clusters = set(int(c) for c in doc_clusters(index, valid))
        self.probed_clusters = set(int(c) for c in probed)

    @property
    def empty(self) -> bool:
        return self.query_vec is None


def doc_clusters(index: IVFIndex, doc_ids: np.ndarray) -> np.ndarray:
    """Map doc ids -> cluster ids via the flat-store offsets."""
    return index.doc_cluster(np.asarray(doc_ids, np.int64))


@dataclasses.dataclass
class ReorderPlan:
    order: list[int]
    n_home: int  # |H_v ∩ C'| prefix length
    n_probed: int  # |(C_v - H_v) ∩ C'| middle length


def reorder_clusters(candidates: list[int], cache: LocalCache) -> ReorderPlan:
    """O2/O3 ordering: H_v∩C' then (C_v − H_v)∩C' then the rest; ties keep
    the centroid-distance order the candidate list arrived in."""
    if cache is None or cache.empty:
        return ReorderPlan(list(candidates), 0, 0)
    hv = cache.home_clusters or set()
    cv = cache.probed_clusters or set()
    first = [c for c in candidates if c in hv]
    second = [c for c in candidates if c not in hv and c in cv]
    rest = [c for c in candidates if c not in hv and c not in cv]
    return ReorderPlan(first + second + rest, len(first), len(second))


def answer_from_cache(
    cache: LocalCache, query_vec: np.ndarray, k: int, *, delta: float
) -> Optional[tuple[np.ndarray, np.ndarray]]:
    """O1: try to answer v' from v's larger-top-k' cache.

    Conclusive iff d(v, v') <= delta AND the cache holds at least k entries
    whose distance to v' (recomputed exactly against cached vectors is not
    possible — the cache stores distances to v, so we use the ball bound):
    every cached entry within  d_i(v) + 2*delta  of the kth is accepted.
    The caller treats a None as "fall through to real search".
    """
    if cache.empty or cache.ids is None:
        return None
    dvv = float(np.linalg.norm(cache.query_vec - query_vec))
    if dvv > delta:
        return None
    valid = cache.ids >= 0
    if valid.sum() < k:
        return None
    # conservative: require a margin between kth and (k'-th) cached distance
    d = np.sqrt(np.maximum(cache.dists[valid], 0.0))
    if d.shape[0] <= k or d[-1] - d[k - 1] < 2.0 * dvv:
        return None
    return cache.dists[valid][:k], cache.ids[valid][:k]


def early_termination_possible(
    index: IVFIndex,
    query_vec: np.ndarray,
    remaining: list[int],
    topk: TopK,
) -> bool:
    """Lossless stop: kth running distance below the lower bound of every
    remaining cluster (centroid distance minus cluster radius, squared)."""
    if not remaining or not np.isfinite(topk.kth):
        return False
    lb = index.cluster_lower_bound(query_vec[None], np.asarray(remaining))
    return bool(topk.kth <= lb.min())


def heuristic_termination_possible(
    index: IVFIndex,
    query_vec: np.ndarray,
    remaining: list[int],
    topk: TopK,
    *,
    margin: float = 0.85,
) -> bool:
    """Centroid-margin approximate stop: terminate when every remaining
    cluster's centroid distance already exceeds margin x the running kth
    distance.  Only meaningful for centroid-ordered scans; reordered scans
    use the patience stop below."""
    if not remaining or not np.isfinite(topk.kth):
        return False
    cd = index.centroid_dists(query_vec[None])[0][np.asarray(remaining)]
    return bool(cd.min() > margin * topk.kth)


def patience_termination(no_improve: int, searched: int, k: int,
                         *, patience: int = 3, min_searched: int = 2) -> bool:
    """ANNS adaptive stop (what the paper's Fig. 9b exploits): terminate when
    the running top-k has not improved for ``patience`` consecutive clusters.
    Similarity reordering surfaces the home clusters first, so the
    no-improvement streak starts earlier — that is precisely the "earlier
    termination" mechanism; recall cost is measured in bench_similarity."""
    return searched >= max(min_searched, 1) and no_improve >= patience


# ---------------------------------------------------------------------------
# Observation statistics (reproduces paper Fig. 9a on any workload)
# ---------------------------------------------------------------------------


def observation_stats(
    index: IVFIndex,
    prev_q: np.ndarray,
    next_q: np.ndarray,
    *,
    k: int = 1,
    k_prime: int = 20,
    nprobe: int = 32,
) -> dict:
    """For a (v, v') pair: does each locality observation hold?"""
    dv, iv = index.search(prev_q[None], nprobe, k_prime)
    dn, inn = index.search(next_q[None], nprobe, k)
    truth = set(int(i) for i in inn[0] if i >= 0)
    o1 = truth.issubset(set(int(i) for i in iv[0] if i >= 0))
    hv = set(int(c) for c in doc_clusters(index, iv[0][iv[0] >= 0]))
    tc = set(int(c) for c in doc_clusters(index, inn[0][inn[0] >= 0]))
    o2 = tc.issubset(hv)
    cv = set(int(c) for c in index.probe_order(prev_q[None], nprobe)[0])
    cn = set(int(c) for c in index.probe_order(next_q[None], nprobe)[0])
    o3 = tc.issubset(cv & cn)
    return {"o1": o1, "o2": o2, "o3": o3}
