"""Speculative execution across dependent stages (paper §4.3, C5).

Two directions:

* **speculative generation** — a Retrieval→Generation edge: once a prefix of
  the (reordered) cluster queue has been searched, the follower generation
  starts from the *partial* top-k.  When the retrieval completes, partial and
  final top-k are compared; mismatch rolls the generation back (it overlapped
  with remaining search, so rollback costs nothing vs. the sequential plan).

* **speculative retrieval** — a Generation→Retrieval edge: the embedding of a
  partial generation (ratio r of expected tokens) launches a warm-up search
  whose results populate the request's LocalCache, so the *real* retrieval
  starts with inter-retrieval history (reordering + O1 cache answers).

Trigger policy (paper): speculate only while the next sub-stage leaves the
engine underutilised — T_curr / T_max < tau — and then pick candidates with
the lowest expected speculation error:

  spec-gen:  retrieval whose running top-k distances are closest to the query
             (small kth distance -> partial result likely final);
  spec-ret:  generation with minimal semantic drift between consecutive
             partial embeddings.

Baseline policies from the paper's comparison are expressible in the same
machinery (the paper itself implements them as speculative edges):
  'ralmspec'  — always speculate from the local cache, no reordering gate;
  'pipeline'  — PipeRAG/RAGCache-style conservative fixed-point speculation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class SpeculationPolicy:
    mode: str = "hedra"  # hedra | ralmspec | pipeline | off
    tau: float = 0.85  # throughput-underutilisation gate
    min_searched_frac: float = 0.25  # spec-gen: prefix of clusters searched
    spec_ret_ratio: float = 0.4  # spec-ret: partial-generation ratio
    max_spec_per_cycle: int = 4
    kth_dist_margin: float = 1.25  # spec-gen candidate quality filter

    @property
    def enabled(self) -> bool:
        return self.mode != "off"


@dataclasses.dataclass
class SpecStats:
    attempted_gen: int = 0
    validated_gen: int = 0
    rolled_back_gen: int = 0
    attempted_ret: int = 0
    useful_ret: int = 0

    @property
    def gen_accuracy(self) -> float:
        n = self.validated_gen + self.rolled_back_gen
        return self.validated_gen / n if n else 0.0


class Speculator:
    def __init__(self, policy: SpeculationPolicy):
        self.policy = policy
        self.stats = SpecStats()

    # ------------------------------------------------------------- gating
    def throughput_gate(self, t_curr: float, t_max: float) -> bool:
        if not self.policy.enabled:
            return False
        if self.policy.mode == "ralmspec":
            return True  # RaLMSpec speculates unconditionally
        return (t_curr / max(t_max, 1e-9)) < self.policy.tau

    # ----------------------------------------------------- candidate scoring
    def spec_gen_ready(self, searched: int, total: int, kth_dist: float,
                       centroid_d0: float) -> bool:
        """Is this retrieval stage a good speculative-generation basis?"""
        if total == 0:
            return False
        frac = searched / total
        if self.policy.mode == "pipeline":
            # conservative: only speculate once most clusters are done
            return frac >= 0.75
        if frac < self.policy.min_searched_frac:
            return False
        if self.policy.mode == "ralmspec":
            return True
        # hedra: quality filter — partial kth distance must already be tight
        # relative to the first-centroid distance scale
        return np.isfinite(kth_dist) and kth_dist <= self.policy.kth_dist_margin * max(
            centroid_d0, 1e-9
        )

    def rank_spec_gen(self, cands: list) -> list:
        """Sort candidates by (kth partial distance / scale): tightest first."""
        return sorted(cands, key=lambda c: c[0])

    # -------------------------------------------------------------- validate
    def validate_gen(self, basis_ids: np.ndarray, final_ids: np.ndarray) -> bool:
        ok = bool(np.array_equal(np.asarray(basis_ids), np.asarray(final_ids)))
        if ok:
            self.stats.validated_gen += 1
        else:
            self.stats.rolled_back_gen += 1
        return ok

    # ---------------------------------------------------------------- drift
    @staticmethod
    def semantic_drift(prev_emb: Optional[np.ndarray], cur_emb: np.ndarray) -> float:
        if prev_emb is None:
            return float("inf")
        return float(np.linalg.norm(prev_emb - cur_emb))
