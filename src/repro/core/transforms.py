"""Graph transformation operators over the runtime sub-node DAG (paper §4).

Each of the paper's four transformation families is a concrete operator with
an estimated-benefit hook, applied by the scheduler to the current wavefront:

  node splitting        split_generation_next / split_retrieval_next
  reordering            reorder_retrieval  (O2/O3 cluster ordering)
  edge addition         add_speculative_generation / add_speculative_retrieval
  dependency rewiring   validate_or_rollback (spec edge resolution), plus
                        RuntimeDAG.rewire for straggler re-dispatch

The operators mutate (RuntimeDAG, RequestContext) and return the materialised
sub-nodes; estimated latency shifts are what §4.5's scheduler sorts on.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.runtime import RequestContext, RuntimeDAG, SubNode
from repro.core.similarity import (
    LocalCache,
    answer_from_cache,
    early_termination_possible,
    patience_termination,
    reorder_clusters,
)
from repro.core.substage import TimeBudget
from repro.core.speculation import Speculator


# ---------------------------------------------------------------------------
# Node splitting (C3)
# ---------------------------------------------------------------------------


def split_generation_next(dag: RuntimeDAG, req: RequestContext,
                          budget: TimeBudget, batch_hint: int = 1,
                          speculative: bool = False,
                          deps=()) -> SubNode:
    """Materialise the next generation sub-node (n decode steps)."""
    assert req.gen is not None
    n = budget.gen_steps_for_budget(batch_hint)
    n = min(n, max(req.gen.target_tokens - req.gen.generated, 1))
    return dag.new_subnode(req, "gen", {"n_steps": n}, deps=deps,
                           speculative=speculative)


def split_retrieval_next(dag: RuntimeDAG, req: RequestContext,
                         budget: TimeBudget, cost_model, sizes,
                         speculative: bool = False, deps=()) -> Optional[SubNode]:
    """Materialise the next retrieval sub-node: clusters admitted from the
    (already reordered) queue until the Eq.(1) budget fills."""
    assert req.ret is not None
    if not req.ret.cluster_queue:
        return None
    n = budget.clusters_for_budget(req.ret.cluster_queue, cost_model, sizes)
    clusters = req.ret.cluster_queue[:n]
    return dag.new_subnode(req, "ret", {"clusters": list(clusters)}, deps=deps,
                           speculative=speculative)


def split_stage_next(dag: RuntimeDAG, req: RequestContext,
                     budget: TimeBudget, unit_costs,
                     *, whole_stage: bool = False,
                     speculative: bool = False, deps=()) -> Optional[SubNode]:
    """Materialise the next sub-node of a generic registry host stage
    (rerank / rewrite / compress / ...): work units admitted from the head
    of the stage queue until the Eq.(1) budget fills (the whole queue for
    coarse whole-stage dispatch).  ``unit_costs`` is the per-unit cost list
    the owning StageSpec computed — the registry's sub-stage factory, the
    direct analogue of ``split_retrieval_next`` for non-cluster units."""
    st = req.stage
    assert st is not None
    if not st.work_queue:
        return None
    n = (len(st.work_queue) if whole_stage
         else budget.units_for_budget(unit_costs))
    units = list(st.work_queue[:n])
    return dag.new_subnode(req, st.kind, {"units": units}, deps=deps,
                           speculative=speculative)


# ---------------------------------------------------------------------------
# Reordering (C4)
# ---------------------------------------------------------------------------


def reorder_retrieval(req: RequestContext) -> dict:
    """Apply O2/O3 similarity ordering to the stage's remaining clusters and
    try the O1 cache answer.  Returns a report for benefit accounting."""
    assert req.ret is not None
    cache: LocalCache = req.sim_cache
    report = {"reordered": False, "cache_answer": False, "n_home": 0, "n_probed": 0}
    if cache is None or cache.empty:
        return report
    hit = answer_from_cache(
        cache, req.ret.query_vec, req.ret.k,
        delta=0.15 * float(np.linalg.norm(req.ret.query_vec)),
    )
    if hit is not None:
        d, i = hit
        req.ret.topk = req.ret.topk.merge(d, i)
        req.ret.answered_from_cache = True
        req.ret.cluster_queue = []
        report["cache_answer"] = True
        return report
    plan = reorder_clusters(req.ret.cluster_queue, cache)
    req.ret.cluster_queue = plan.order
    report.update(reordered=True, n_home=plan.n_home, n_probed=plan.n_probed)
    return report


def maybe_early_terminate(index, req: RequestContext,
                          mode: str = "heuristic", patience: int = 3) -> bool:
    """Post-sub-stage termination check (enabled by reordering).
    mode='lossless' uses the triangle-inequality bound (result-preserving);
    mode='heuristic' uses the ANNS patience stop (paper behaviour: earlier
    termination once reordering surfaces good clusters first; recall cost
    measured in benchmarks/bench_similarity.py)."""
    assert req.ret is not None
    if req.ret.done:
        return False
    if mode == "heuristic":
        fire = patience_termination(req.ret.no_improve, len(req.ret.searched),
                                    req.ret.k, patience=patience)
    else:
        fire = early_termination_possible(
            index, req.ret.query_vec, req.ret.cluster_queue, req.ret.topk)
    if fire:
        req.ret.early_terminated = True
        req.ret.cluster_queue = []
        return True
    return False


# ---------------------------------------------------------------------------
# Speculative edge addition (C5)
# ---------------------------------------------------------------------------


def add_speculative_generation(dag: RuntimeDAG, req: RequestContext,
                               basis: SubNode, target_node,
                               target_tokens: int, budget: TimeBudget) -> SubNode:
    """Start the follower Generation node from partial retrieval results.
    The speculative sub-node depends only on the *basis* retrieval sub-node,
    not on the full stage — that is the added edge."""
    from repro.core.runtime import GenProgress

    req.gen = GenProgress(target_tokens=target_tokens,
                          speculative_src=basis.sid,
                          spec_basis=req.ret.topk.ids.copy(),
                          node_id=target_node.node_id)
    sn = split_generation_next(dag, req, budget, speculative=True,
                               deps={basis.sid})
    dag.add_spec_edge(basis, sn)
    return sn


def validate_or_rollback(dag: RuntimeDAG, req: RequestContext,
                         spec: Speculator) -> bool:
    """Dependency rewiring at retrieval completion: if the partial top-k the
    speculative generation consumed equals the final top-k, the speculative
    sub-nodes become the real ones (rewired to depend on the completed
    stage); otherwise they are invalidated and generation restarts."""
    assert req.gen is not None and req.ret is not None
    ok = spec.validate_gen(req.gen.spec_basis, req.ret.topk.ids)
    if ok:
        req.gen.speculative_src = None
        req.gen.spec_basis = None
        for sn in dag.subnodes.values():
            if sn.req is req and sn.kind == "gen":
                sn.speculative = False
        return True
    # rollback: invalidate speculative work, restart the generation stage
    for sn in list(dag.subnodes.values()):
        if sn.req is req and sn.kind == "gen" and sn.speculative:
            dag.invalidate(sn)
    tgt, nid = req.gen.target_tokens, req.gen.node_id
    from repro.core.runtime import GenProgress

    req.gen = GenProgress(target_tokens=tgt, node_id=nid)
    return False
