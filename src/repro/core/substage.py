"""Dynamic sub-stage partitioning + the Eq. (1) time budget.

The paper sets the retrieval sub-stage time budget ``mb`` by maximising the
expected latency improvement

    Delta_l(mb) = (t_Retrieval - mb) / 2  -  (t_Retrieval / mb) * beta

(first term: expected wait-time reduction when a stage can join mid-flight;
second term: scheduling/intermediate-result overhead of the extra
sub-stages; the paper's printed formula adds the overhead term — a sign typo,
since the stated argmax then has no interior optimum).  Setting the
derivative to zero gives the closed form

    mb* = sqrt(2 * t_Retrieval * beta)

``t_Retrieval`` and ``beta`` are measured online (EMA), so the budget adapts
to the live workload exactly as §4.2 describes.  Generation sub-stages are
sized to match: n_steps = clamp(mb / t_decode_step).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass
class TimeBudget:
    beta_us: float = 150.0  # per-sub-stage scheduling overhead (measured)
    t_retrieval_us: float = 20_000.0  # average full retrieval stage time (EMA)
    t_decode_step_us: float = 1_000.0  # per decode step (EMA, batch-dependent)
    ema: float = 0.9
    min_budget_us: float = 200.0
    max_budget_us: float = 200_000.0

    def observe_retrieval_stage(self, t_us: float) -> None:
        self.t_retrieval_us = self.ema * self.t_retrieval_us + (1 - self.ema) * t_us

    def observe_decode_step(self, t_us: float) -> None:
        self.t_decode_step_us = self.ema * self.t_decode_step_us + (1 - self.ema) * t_us

    def observe_beta(self, t_us: float) -> None:
        self.beta_us = self.ema * self.beta_us + (1 - self.ema) * t_us

    @property
    def mb_us(self) -> float:
        mb = math.sqrt(2.0 * max(self.t_retrieval_us, 1e-9) * max(self.beta_us, 1e-9))
        return min(max(mb, self.min_budget_us), self.max_budget_us)

    def delta_l(self, mb_us: float) -> float:
        """Expected latency improvement at a given budget (for analysis)."""
        return (self.t_retrieval_us - mb_us) / 2.0 - (
            self.t_retrieval_us / max(mb_us, 1e-9)
        ) * self.beta_us

    # ---------------------------------------------------------------- sizing
    def gen_steps_for_budget(self, batch_hint: int = 1) -> int:
        n = int(self.mb_us / max(self.t_decode_step_us, 1.0))
        return max(1, min(n, 64))

    def units_for_budget(self, unit_costs) -> int:
        """Generic Eq.(1) sizing for any splittable stage: admit work units
        (clusters, candidate blocks, query variants, ...) from the head of
        the queue until the budget fills; at least one unit always fits so
        progress is guaranteed.  Stage specs hand in their per-unit cost
        profile (see core/stages.py)."""
        budget = self.mb_us
        used = 0.0
        n = 0
        for c in unit_costs:
            if n > 0 and used + c > budget:
                break
            used += c
            n += 1
        return max(n, 1) if len(unit_costs) else 0

    def clusters_for_budget(self, cluster_queue, cost_model, sizes) -> int:
        """Incrementally admit clusters until the budget is filled (§4.2):
        returns how many clusters from the head of the queue fit in mb."""
        return self.units_for_budget(
            [cost_model.cost_us(int(sizes[cid])) for cid in cluster_queue])
