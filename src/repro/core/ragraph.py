"""RAGraph: the paper's graph abstraction for heterogeneous RAG workflows.

Matches Listing 1 of the paper:

    g = RAGraph()
    g.add_generation(0, prompt="Generate a hypothesis for {input}.",
                     output="hypopara")
    g.add_retrieval(1, topk=5, query="hypopara", output="docs")
    g.add_generation(2, prompt="Answer {query} using {docs}.")
    g.add_edge(START, 0); g.add_edge(0, 1)
    g.add_edge(1, 2); g.add_edge(2, END)
    # conditional control flow:
    g.add_edge(2, lambda s: 1 if s.get("subquestion") else END)

Nodes capture the *execution asymmetry* the paper highlights: a Retrieval
node is a structurally-bounded sequence of cluster searches; a Generation
node is an open-ended token-level process.  Both are therefore splittable
into sub-stages (see transforms.py) — that property is what the whole
scheduler exploits.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Union


class _Sentinel:
    def __init__(self, name: str):
        self.name = name

    def __repr__(self):
        return self.name


START = _Sentinel("START")
END = _Sentinel("END")

NodeId = int
EdgeTarget = Union[NodeId, _Sentinel, Callable[[dict], Union[NodeId, _Sentinel]]]


@dataclasses.dataclass(frozen=True)
class GenerationNode:
    node_id: NodeId
    prompt: str
    output: str = "answer"
    max_tokens: int = 256
    # declarative knobs the scheduler may use
    emit_partial_embeddings: bool = True  # allow speculative retrieval from it

    kind = "generation"

    def inputs(self) -> list[str]:
        import string

        return [f[1] for f in string.Formatter().parse(self.prompt) if f[1]]


@dataclasses.dataclass(frozen=True)
class RetrievalNode:
    node_id: NodeId
    query: str  # state key holding the query text/embedding source
    output: str = "docs"
    topk: int = 5
    nprobe: int = 0  # 0 -> server default

    kind = "retrieval"

    def inputs(self) -> list[str]:
        return [self.query]


Node = Union[GenerationNode, RetrievalNode]


class RAGraph:
    """User-facing workflow graph (static structure; per-request state lives
    in RequestContext)."""

    def __init__(self, name: str = "ragraph"):
        self.name = name
        self.nodes: dict[NodeId, Node] = {}
        self.edges: dict[Any, list[EdgeTarget]] = {}

    # ------------------------------------------------------------ primitives
    def add_generation(self, node_id: NodeId, prompt: str, output: str = "answer",
                       max_tokens: int = 256, **kw) -> "RAGraph":
        if node_id in self.nodes:
            raise ValueError(f"duplicate node id {node_id}")
        self.nodes[node_id] = GenerationNode(node_id, prompt, output, max_tokens, **kw)
        return self

    def add_retrieval(self, node_id: NodeId, query: str, output: str = "docs",
                      topk: int = 5, nprobe: int = 0) -> "RAGraph":
        if node_id in self.nodes:
            raise ValueError(f"duplicate node id {node_id}")
        self.nodes[node_id] = RetrievalNode(node_id, query, output, topk, nprobe)
        return self

    def add_edge(self, src: Union[NodeId, _Sentinel], dst: EdgeTarget) -> "RAGraph":
        self.edges.setdefault(_key(src), []).append(dst)
        return self

    # ------------------------------------------------------------- traversal
    def entry(self) -> NodeId:
        outs = self.edges.get("START", [])
        if not outs:
            raise ValueError("graph has no START edge")
        first = outs[0]
        if callable(first):
            raise ValueError("START edge must be unconditional")
        assert not isinstance(first, _Sentinel)
        return first

    def successor(self, node_id: NodeId, state: dict) -> Union[NodeId, _Sentinel]:
        """Resolve the next node given per-request state (conditional edges
        are evaluated in insertion order; first non-None wins)."""
        for tgt in self.edges.get(_key(node_id), []):
            if callable(tgt):
                r = tgt(state)
                if r is not None:
                    return r
            else:
                return tgt
        return END

    def validate(self) -> None:
        if "START" not in self.edges:
            raise ValueError("missing START edge")
        for src, dsts in self.edges.items():
            if src not in ("START",) and src not in self.nodes:
                raise ValueError(f"edge from unknown node {src}")
            for d in dsts:
                if callable(d) or isinstance(d, _Sentinel):
                    continue
                if d not in self.nodes:
                    raise ValueError(f"edge to unknown node {d}")

    # ----------------------------------------------------- interop adapters
    @classmethod
    def from_langchain_steps(cls, steps: list[dict], name: str = "imported") -> "RAGraph":
        """Import a linear LangChain/LlamaIndex-style chain:
        [{"type": "llm"|"retriever", ...kwargs}] -> RAGraph."""
        g = cls(name)
        prev: Union[NodeId, _Sentinel] = START
        for i, s in enumerate(steps):
            if s["type"] in ("llm", "generation"):
                g.add_generation(i, prompt=s.get("prompt", "{input}"),
                                 output=s.get("output", f"gen_{i}"),
                                 max_tokens=s.get("max_tokens", 256))
            elif s["type"] in ("retriever", "retrieval"):
                g.add_retrieval(i, query=s.get("query", "input"),
                                output=s.get("output", f"docs_{i}"),
                                topk=s.get("topk", 5))
            else:
                raise ValueError(f"unknown step type {s['type']}")
            g.add_edge(prev, i)
            prev = i
        g.add_edge(prev, END)
        return g


def _key(x):
    return "START" if x is START else x
