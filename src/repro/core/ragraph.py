"""RAGraph: the paper's graph abstraction for heterogeneous RAG workflows.

The construction API follows Listing 1 of the paper:

    g = RAGraph()
    g.add_generation(0, prompt="Generate a hypothesis for {input}.",
                     output="hypopara")
    g.add_retrieval(1, topk=5, query="hypopara", output="docs")
    g.add_generation(2, prompt="Answer {query} using {docs}.")
    g.add_edge(START, 0); g.add_edge(0, 1)
    g.add_edge(1, 2); g.add_edge(2, END)
    # conditional control flow:
    g.add_edge(2, lambda s: 1 if s.get("subquestion") else END)

Beyond Listing 1's two node kinds, the node model is *stage-polymorphic*:
each node dataclass here is plain data (id, wiring keys, knobs) tagged with
a ``kind`` string, and everything behavioural — how a stage enters/executes/
splits/finishes, what it costs, how it deduplicates — lives in the matching
``StageSpec`` registered in :mod:`repro.core.stages`.  The scheduler layers
(``core/wavefront.py``, ``serving/dispatch.py``, ``crossreq/dedup.py``)
dispatch through that registry, so new stage types plug in without touching
the scheduler.  Registered kinds:

    generation  open-ended token process on the accelerator (splittable by
                decode steps)
    retrieval   structurally-bounded IVF cluster-scan sequence on the host
                (splittable by cluster; optional dense+lexical hybrid
                fusion via ``lexical_weight``)
    rerank      cross-encoder scoring over retrieved candidates (splittable
                by candidate block)
    rewrite     multi-query expansion fanning out N retrieval sub-searches
                whose results k-way merge through the BatchTopK fold
    compress    extractive context compression by block saliency
                (splittable by candidate block)

Nodes capture the *execution asymmetry* the paper highlights: host-side
stages are bounded unit sequences, generation is open-ended — and every
registered stage declares its splittability, which is what the whole
scheduler exploits (see transforms.py / stages.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Union


class _Sentinel:
    def __init__(self, name: str):
        self.name = name

    def __repr__(self):
        return self.name


START = _Sentinel("START")
END = _Sentinel("END")

NodeId = int
EdgeTarget = Union[NodeId, _Sentinel, Callable[[dict], Union[NodeId, _Sentinel]]]


@dataclasses.dataclass(frozen=True)
class GenerationNode:
    node_id: NodeId
    prompt: str
    output: str = "answer"
    max_tokens: int = 256
    # declarative knobs the scheduler may use
    emit_partial_embeddings: bool = True  # allow speculative retrieval from it

    kind = "generation"

    def inputs(self) -> list[str]:
        import string

        return [f[1] for f in string.Formatter().parse(self.prompt) if f[1]]


@dataclasses.dataclass(frozen=True)
class RetrievalNode:
    node_id: NodeId
    query: str  # state key holding the query text/embedding source
    output: str = "docs"
    topk: int = 5
    nprobe: int = 0  # 0 -> server default
    # dense+lexical hybrid fusion: weight of the lexical (term-match) score
    # in the reciprocal-rank fusion of the stage's final candidates.  0.0
    # (default) keeps the pure dense path bit-identical to the pre-hybrid
    # behaviour; > 0 rescores the dense top-k with the backend's lexical
    # scorer at stage completion (an instant transform, like reorders).
    lexical_weight: float = 0.0

    kind = "retrieval"

    def inputs(self) -> list[str]:
        return [self.query]


@dataclasses.dataclass(frozen=True)
class RerankNode:
    """Cross-encoder rescoring of retrieved candidates: reads the doc-id
    list at ``docs``, scores every (query, doc) pair with the backend's
    interaction model, keeps the best ``keep``.  Splittable by candidate
    block (``block`` docs per sub-stage unit)."""

    node_id: NodeId
    docs: str  # state key holding the candidate doc-id list
    output: str = "docs"
    keep: int = 5
    block: int = 8  # candidate docs per splittable work unit
    query: str = "input"  # state key whose query embedding anchors scoring

    kind = "rerank"

    def inputs(self) -> list[str]:
        return [self.docs, self.query]


@dataclasses.dataclass(frozen=True)
class RewriteNode:
    """Multi-query expansion: derives ``n_queries`` query variants from the
    request's query embedding, fans out one retrieval sub-search per
    variant, and k-way merges the per-variant top-k sets through the
    ``BatchTopK`` gather fold.  Splittable by variant."""

    node_id: NodeId
    query: str = "input"
    output: str = "docs"
    n_queries: int = 3
    topk: int = 5
    nprobe: int = 0  # 0 -> server default

    kind = "rewrite"

    def inputs(self) -> list[str]:
        return [self.query]


@dataclasses.dataclass(frozen=True)
class CompressNode:
    """Extractive context compression: scores retrieved docs by block
    saliency (training/compression.py's per-block absmax rule) crossed with
    query affinity and keeps the top ``ratio`` fraction.  Splittable by
    candidate block."""

    node_id: NodeId
    docs: str
    output: str = "docs"
    ratio: float = 0.5  # fraction of candidates kept (at least 1)
    block: int = 8
    query: str = "input"

    kind = "compress"

    def inputs(self) -> list[str]:
        return [self.docs, self.query]


Node = Union[GenerationNode, RetrievalNode, RerankNode, RewriteNode,
             CompressNode]


class RAGraph:
    """User-facing workflow graph (static structure; per-request state lives
    in RequestContext)."""

    def __init__(self, name: str = "ragraph"):
        self.name = name
        self.nodes: dict[NodeId, Node] = {}
        self.edges: dict[Any, list[EdgeTarget]] = {}

    # ------------------------------------------------------------ primitives
    def add_node(self, node: Node) -> "RAGraph":
        """Register a pre-built stage node (any kind known to the stage
        registry)."""
        if node.node_id in self.nodes:
            raise ValueError(f"duplicate node id {node.node_id}")
        self.nodes[node.node_id] = node
        return self

    def add_generation(self, node_id: NodeId, prompt: str, output: str = "answer",
                       max_tokens: int = 256, **kw) -> "RAGraph":
        return self.add_node(
            GenerationNode(node_id, prompt, output, max_tokens, **kw))

    def add_retrieval(self, node_id: NodeId, query: str, output: str = "docs",
                      topk: int = 5, nprobe: int = 0, **kw) -> "RAGraph":
        return self.add_node(
            RetrievalNode(node_id, query, output, topk, nprobe, **kw))

    def add_rerank(self, node_id: NodeId, docs: str, output: str = "docs",
                   keep: int = 5, **kw) -> "RAGraph":
        return self.add_node(RerankNode(node_id, docs, output, keep, **kw))

    def add_rewrite(self, node_id: NodeId, query: str = "input",
                    output: str = "docs", n_queries: int = 3,
                    **kw) -> "RAGraph":
        return self.add_node(
            RewriteNode(node_id, query, output, n_queries, **kw))

    def add_compress(self, node_id: NodeId, docs: str, output: str = "docs",
                     ratio: float = 0.5, **kw) -> "RAGraph":
        return self.add_node(CompressNode(node_id, docs, output, ratio, **kw))

    def add_edge(self, src: Union[NodeId, _Sentinel], dst: EdgeTarget) -> "RAGraph":
        self.edges.setdefault(_key(src), []).append(dst)
        return self

    # ------------------------------------------------------------- traversal
    def entry(self) -> NodeId:
        outs = self.edges.get("START", [])
        if not outs:
            raise ValueError("graph has no START edge")
        first = outs[0]
        if callable(first):
            raise ValueError("START edge must be unconditional")
        assert not isinstance(first, _Sentinel)
        return first

    def successor(self, node_id: NodeId, state: dict) -> Union[NodeId, _Sentinel]:
        """Resolve the next node given per-request state (conditional edges
        are evaluated in insertion order; first non-None wins)."""
        for tgt in self.edges.get(_key(node_id), []):
            if callable(tgt):
                r = tgt(state)
                if r is not None:
                    return r
            else:
                return tgt
        return END

    def validate(self) -> None:
        """Static well-formedness checks with actionable errors, run at
        Server admission — malformed graphs fail here instead of deep
        inside the scheduler loop.

        * every edge endpoint names a known node;
        * a START edge exists (and is unconditional — ``entry`` enforces);
        * every node is reachable from START.  Conditional (callable) edges
          cannot be enumerated statically, so a node carrying one is
          treated as potentially reaching any node — no false positives on
          data-dependent loops, at the cost of weaker coverage there;
        * every node has a path onward (at least one outgoing edge — with
          none, ``successor`` would route it straight to END, which is
          almost always a forgotten ``add_edge``);
        * every template input a node declares (``{field}`` in a prompt,
          a query/docs state key) is either the request ``input``, a
          runtime-provided ``_``-prefixed key, or some node's output.
        """
        if "START" not in self.edges:
            raise ValueError(f"graph {self.name!r}: missing START edge")
        for src, dsts in self.edges.items():
            if src not in ("START",) and src not in self.nodes:
                raise ValueError(
                    f"graph {self.name!r}: edge from unknown node {src}")
            for d in dsts:
                if callable(d) or isinstance(d, _Sentinel):
                    continue
                if d not in self.nodes:
                    raise ValueError(
                        f"graph {self.name!r}: edge to unknown node {d}")
        self.entry()
        # reachability from START (callable edges conservatively reach all)
        seen: set = set()
        frontier = ["START"]
        while frontier:
            src = frontier.pop()
            for d in self.edges.get(src, []):
                if callable(d):
                    targets = list(self.nodes)  # cannot enumerate: assume any
                elif isinstance(d, _Sentinel):
                    continue
                else:
                    targets = [d]
                for t in targets:
                    if t not in seen:
                        seen.add(t)
                        frontier.append(t)
        unreachable = sorted(set(self.nodes) - seen)
        if unreachable:
            raise ValueError(
                f"graph {self.name!r}: nodes {unreachable} unreachable from "
                f"START — add an edge into them or remove them")
        # onward paths: a node with no outgoing edge list silently falls to
        # END, which in practice is a forgotten add_edge
        dangling = sorted(n for n in self.nodes
                          if not self.edges.get(_key(n)))
        if dangling:
            raise ValueError(
                f"graph {self.name!r}: nodes {dangling} have no outgoing "
                f"edge — add add_edge(n, END) if termination is intended")
        # dataflow: every declared input must be satisfiable.  "input" is
        # the request text; "query" is Listing 1's builtin alias for it
        produced = {"input", "query"} | {n.output for n in self.nodes.values()}
        for n in self.nodes.values():
            for name in n.inputs():
                if name.startswith("_") or name in produced:
                    continue
                raise ValueError(
                    f"graph {self.name!r}: node {n.node_id} ({n.kind}) "
                    f"reads {name!r}, which no node produces — available "
                    f"keys: {sorted(produced)}")

    # ----------------------------------------------------- interop adapters
    @classmethod
    def from_langchain_steps(cls, steps: list[dict], name: str = "imported") -> "RAGraph":
        """Import a linear LangChain/LlamaIndex-style chain:
        [{"type": "llm"|"retriever", ...kwargs}] -> RAGraph."""
        g = cls(name)
        prev: Union[NodeId, _Sentinel] = START
        for i, s in enumerate(steps):
            if s["type"] in ("llm", "generation"):
                g.add_generation(i, prompt=s.get("prompt", "{input}"),
                                 output=s.get("output", f"gen_{i}"),
                                 max_tokens=s.get("max_tokens", 256))
            elif s["type"] in ("retriever", "retrieval"):
                g.add_retrieval(i, query=s.get("query", "input"),
                                output=s.get("output", f"docs_{i}"),
                                topk=s.get("topk", 5))
            else:
                raise ValueError(f"unknown step type {s['type']}")
            g.add_edge(prev, i)
            prev = i
        g.add_edge(prev, END)
        return g


def _key(x):
    return "START" if x is START else x
