"""Per-request latency attribution / critical-path analysis.

Each finished request's measured latency (``finish - arrival`` on the
virtual clock) is decomposed into exhaustive, non-overlapping components
using the categorized intervals the :class:`~repro.obs.trace.TraceRecorder`
collected:

``queueing``
    time covered by no span at all — waiting in the admission heap, for a
    batch slot, or for a busy worker;
``retrieval_compute`` / ``generation_compute`` / ``stage_compute``
    time the request was (co-)resident in a retrieval scan, a generation
    batch, or a host stage batch;
``merge``
    shard scatter/gather k-way merge points (zero-width on the virtual
    clock — the merge is charged to the part scans — kept as a component
    so the decomposition names every structural step);
``retry_hedge_failover``
    backoff gaps between a transiently failed / timed-out unit and its
    re-dispatch;
``fault_recovery``
    compute lost to a dead worker (fenced results) plus the gap until the
    replacement dispatch.

The decomposition is a *priority sweep* over elementary segments: every
interval boundary inside ``[arrival, finish]`` splits the timeline, each
elementary segment is charged to the single highest-priority component
covering it (compute beats overhead beats recovery; uncovered segments are
queueing), so the components partition the latency exactly — their sum
equals the measured latency by construction, up to float rounding.  The
run-level report (``Server.attribution_report()``) verifies that residual
against a relative tolerance and aggregates totals, fractions and the
per-workflow bottleneck component.
"""
from __future__ import annotations

from typing import Optional

ATTRIBUTION_COMPONENTS = (
    "queueing",
    "retrieval_compute",
    "generation_compute",
    "stage_compute",
    "merge",
    "retry_hedge_failover",
    "fault_recovery",
)

# a segment covered by several span categories is charged to the highest
# priority: actual compute > structural overhead > recovery wait.  Uncovered
# segments fall through to queueing.
_PRIORITY = {
    "generation_compute": 6,
    "retrieval_compute": 5,
    "stage_compute": 4,
    "merge": 3,
    "retry_hedge_failover": 2,
    "fault_recovery": 1,
}


def sweep(intervals, start_us: float, end_us: float) -> dict:
    """Priority sweep of ``[start, end, component]`` rows clipped to
    ``[start_us, end_us]``.  Returns ``{component: us}`` over *all*
    components (zeros included) whose values sum to ``end_us - start_us``
    exactly (up to float rounding)."""
    out = {c: 0.0 for c in ATTRIBUTION_COMPONENTS}
    start_us = float(start_us)
    end_us = float(end_us)
    if end_us <= start_us:
        return out
    clipped = []
    cuts = {start_us, end_us}
    for row in intervals:
        s, e, comp = float(row[0]), float(row[1]), row[2]
        s = max(s, start_us)
        e = min(e, end_us)
        if e <= s:
            continue
        clipped.append((s, e, comp))
        cuts.add(s)
        cuts.add(e)
    bounds = sorted(cuts)
    for a, b in zip(bounds[:-1], bounds[1:]):
        best = None
        for s, e, comp in clipped:
            if s <= a and e >= b:
                if best is None or _PRIORITY[comp] > _PRIORITY[best]:
                    best = comp
        out[best if best is not None else "queueing"] += b - a
    return out


def attribute_request(entry) -> Optional[dict]:
    """Decompose one finished request (a ``TraceRecorder`` per-request
    entry).  Returns None for a request that never finished."""
    if entry.finish_us is None:
        return None
    latency = float(entry.finish_us) - float(entry.arrival_us)
    comps = sweep(entry.intervals, entry.arrival_us, entry.finish_us)
    total = sum(comps.values())
    residual = abs(total - latency)
    rel = residual / latency if latency > 0 else residual
    return {
        "request": entry.rid,
        "workflow": entry.workflow,
        "arrival_us": float(entry.arrival_us),
        "finish_us": float(entry.finish_us),
        "latency_us": latency,
        "degraded": bool(entry.degraded),
        "components_us": comps,
        "residual_us": residual,
        "rel_residual": rel,
    }


def attribution_report(recorder, *, check: bool = True,
                       rel_tol: float = 1e-6) -> dict:
    """Run-level attribution over every finished request in ``recorder``.

    With ``check=True`` (the default) raises ``ValueError`` if any
    request's components fail to sum to its measured latency within
    ``rel_tol`` relative tolerance — the decomposition is exhaustive by
    construction, so a violation means the recorder missed a span.
    """
    rows = []
    for rid in sorted(recorder.requests):
        row = attribute_request(recorder.requests[rid])
        if row is not None:
            rows.append(row)
    max_rel = max((r["rel_residual"] for r in rows), default=0.0)
    if check and max_rel > rel_tol:
        worst = max(rows, key=lambda r: r["rel_residual"])
        raise ValueError(
            f"attribution residual {worst['rel_residual']:.3e} for request "
            f"{worst['request']} exceeds rel_tol={rel_tol:.1e} "
            f"(components {worst['components_us']}, "
            f"latency {worst['latency_us']})")

    totals = {c: 0.0 for c in ATTRIBUTION_COMPONENTS}
    by_wf: dict[str, dict] = {}
    for r in rows:
        for c, v in r["components_us"].items():
            totals[c] += v
        wf = by_wf.setdefault(r["workflow"], {
            "finished": 0, "latency_us": 0.0,
            "components_us": {c: 0.0 for c in ATTRIBUTION_COMPONENTS},
        })
        wf["finished"] += 1
        wf["latency_us"] += r["latency_us"]
        for c, v in r["components_us"].items():
            wf["components_us"][c] += v
    grand = sum(totals.values())
    n = len(rows)
    for wf in by_wf.values():
        tot = max(sum(wf["components_us"].values()), 1e-12)
        wf["fractions"] = {c: v / tot
                           for c, v in wf["components_us"].items()}
        wf["bottleneck"] = max(wf["components_us"],
                               key=lambda c: wf["components_us"][c])
        wf["mean_latency_us"] = wf["latency_us"] / max(wf["finished"], 1)
    return {
        "finished": n,
        "totals_us": totals,
        "fractions": {c: (v / grand if grand > 0 else 0.0)
                      for c, v in totals.items()},
        "means_us": {c: (v / n if n else 0.0) for c, v in totals.items()},
        "bottleneck": max(totals, key=lambda c: totals[c]) if n else None,
        "by_workflow": {k: by_wf[k] for k in sorted(by_wf)},
        "max_rel_residual": max_rel,
        "rel_tol": rel_tol,
        "per_request": rows,
    }
