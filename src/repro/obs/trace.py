"""Span tracing for the wavefront serving loop.

The :class:`TraceRecorder` is a *passive* observer the scheduler feeds when
``SchedulerConfig.tracing`` is on: every dispatched job (generation batch,
retrieval plan, host stage batch), every shard scatter/gather, hedge twin,
fusion fan-out, retry, failover, and lifecycle transition is recorded as a
span or instant on a per-resource track — the virtual clock supplies the
timestamps, so the trace reconstructs exactly the timeline the scheduler
executed.  Recording never draws randomness, never mutates scheduler state,
and never touches per-request event logs; enabling it leaves serving
bit-identical.

``to_chrome()`` renders the record as Chrome trace-event JSON (the
``traceEvents`` array format), which both ``chrome://tracing`` and Perfetto
open directly:

* one *track* (pid/tid pair) per resource — the admission queue /
  scheduler, the generation engine, and each retrieval worker;
* ``X`` (complete) events for work spans, ``i`` instants for arrivals,
  merges, fusions, failovers, and lifecycle transitions;
* ``s``/``f`` flow events linking a request's consecutive sub-stages,
  scatter parts to their gather merge, original jobs to their hedge twins,
  dedup leaders to fanned-out followers, and lost work to its failover
  re-dispatch.

The same record doubles as the input to ``obs.attribution``: every span
contributes a categorized per-request interval (generation / retrieval /
stage compute, merge, retry and fault-recovery wait gaps).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.core.ownership import handoff, owned_by

# track keys ---------------------------------------------------------------
QUEUE_TRACK = ("queue",)
GEN_TRACK = ("gen",)


def ret_track(wid: int) -> tuple:
    return ("ret", int(wid))


def _tid(track: tuple) -> int:
    if track == QUEUE_TRACK:
        return 0
    if track == GEN_TRACK:
        return 1
    return 10 + int(track[1])


def _track_name(track: tuple) -> str:
    if track == QUEUE_TRACK:
        return "admission queue / scheduler"
    if track == GEN_TRACK:
        return "gen engine"
    return f"retrieval worker {track[1]}"


_PID = 1  # single virtual process: the server


@dataclasses.dataclass
class _ReqTrace:
    """Per-request bookkeeping: the attribution intervals plus the frontier
    state that turns consecutive spans into dependency flow edges."""

    rid: int
    arrival_us: float
    workflow: str
    slo_us: float
    finish_us: Optional[float] = None
    degraded: bool = False
    # [start_us, end_us, component] — mutable so a lost job's compute can be
    # reclassified as fault recovery after the fact
    intervals: list = dataclasses.field(default_factory=list)
    # (track, ts) flow-edge source for the next dispatched span; spans
    # overlapping the current frontier (parallel scatter parts, hedge twins)
    # fan out from the same source instead of chaining serially
    fan_src: Optional[tuple] = None
    frontier: Optional[tuple] = None  # (track, end_us) of furthest span
    gap: Optional[tuple] = None  # (start_us, component) open wait gap


@owned_by("obs")
class TraceRecorder:
    def __init__(self):
        self.spans: list[dict] = []
        self.instants: list[dict] = []
        self.flows: list[dict] = []
        self.requests: dict[int, _ReqTrace] = {}
        self._gather_parts: dict[int, list] = {}  # id(gather) -> flow points
        # id(job) -> (span, attribution rows): recorder-owned side tables —
        # stashing these on the scheduler's job dicts would make the
        # recorder a writer of scheduler state (hooks/obs-mutation)
        self._job_spans: dict[int, dict] = {}
        self._job_rows: dict[int, list] = {}
        self._next_flow = 0

    # ------------------------------------------------------------ low level
    def _req(self, req) -> _ReqTrace:
        e = self.requests.get(req.request_id)
        if e is None:
            e = _ReqTrace(rid=req.request_id,
                          arrival_us=float(req.arrival_us),
                          workflow=req.graph.name,
                          slo_us=float(req.slo_us or 0.0))
            e.fan_src = (QUEUE_TRACK, e.arrival_us)
            self.requests[req.request_id] = e
        return e

    def _span(self, track: tuple, name: str, ts: float, dur: float,
              cat: str, args: dict) -> dict:
        s = {"track": track, "name": name, "ts": float(ts),
             "dur": float(dur), "cat": cat, "args": args}
        self.spans.append(s)
        return s

    def _instant(self, track: tuple, name: str, ts: float, cat: str,
                 args: Optional[dict] = None) -> dict:
        i = {"track": track, "name": name, "ts": float(ts), "cat": cat,
             "args": args or {}}
        self.instants.append(i)
        return i

    def _flow(self, cat: str, src: tuple, dst: tuple,
              name: str = "") -> None:
        self.flows.append({"fid": self._next_flow, "cat": cat,
                           "name": name or cat,
                           "src": (src[0], float(src[1])),
                           "dst": (dst[0], float(dst[1]))})
        self._next_flow += 1

    def _attach(self, req, track: tuple, ts: float, end: float,
                component: str) -> list:
        """Register a work span's interval for ``req`` and emit the
        dependency flow edge from the request's frontier.  Returns the
        (mutable) interval row so a lost job can reclassify it later."""
        e = self._req(req)
        flow_cat = "dep"
        if e.gap is not None:
            g0, gcomp = e.gap
            if ts > g0:
                e.intervals.append([g0, float(ts), gcomp])
            e.gap = None
            flow_cat = ("failover" if gcomp == "fault_recovery"
                        else "retry")
        if e.frontier is not None and ts >= e.frontier[1] - 1e-9:
            # strictly after all prior work: a new hop in the chain
            e.fan_src = e.frontier
        if e.fan_src is not None:
            self._flow(flow_cat, e.fan_src, (track, ts),
                       name=f"r{e.rid}")
        if e.frontier is None or end > e.frontier[1]:
            e.frontier = (track, end)
        row = [float(ts), float(end), component]
        e.intervals.append(row)
        return row

    # ----------------------------------------------------- scheduler hooks
    @handoff("scheduler")
    def request_submitted(self, req, now: float) -> None:
        e = self._req(req)
        self._instant(QUEUE_TRACK, f"arrive r{e.rid}", e.arrival_us,
                      "request", {"request": e.rid, "workflow": e.workflow,
                                  "slo_us": e.slo_us})

    @handoff("scheduler")
    def request_shed(self, req, now: float, reason: str) -> None:
        self._instant(QUEUE_TRACK, f"shed r{req.request_id}",
                      float(max(now, req.arrival_us)), "shed",
                      {"request": req.request_id, "reason": reason,
                       "workflow": req.graph.name})

    @handoff("scheduler")
    def request_finished(self, req, now: float) -> None:
        e = self._req(req)
        if e.gap is not None:
            g0, gcomp = e.gap
            if now > g0:
                e.intervals.append([g0, float(now), gcomp])
            e.gap = None
        e.finish_us = float(now)
        e.degraded = bool(req.state.get("_degraded"))
        self._instant(QUEUE_TRACK, f"finish r{e.rid}", now, "request",
                      {"request": e.rid, "workflow": e.workflow,
                       "latency_us": float(now) - e.arrival_us,
                       "degraded": e.degraded})

    @handoff("scheduler")
    def gen_job(self, job, now: float) -> None:
        reqs = job["reqs"]
        rids = [r.request_id for r in reqs]
        span = self._span(
            GEN_TRACK, f"gen b{len(reqs)} s{job['n_steps']}", now,
            job["end"] - now, "gen",
            {"requests": rids, "n_steps": int(job["n_steps"])})
        self._job_spans[id(job)] = span
        rows = []
        for r in reqs:
            rows.append(self._attach(r, GEN_TRACK, now, job["end"],
                                     "generation_compute"))
        self._job_rows[id(job)] = rows

    @handoff("scheduler")
    def ret_job(self, job, wid: int, now: float, hedge: bool) -> None:
        track = ret_track(wid)
        end = float(job["end"])
        kinds: dict[str, int] = {}
        rids: list[int] = []
        rows = []
        plan = job["plan"]
        if plan is not None:
            for g, meta in enumerate(plan.group_meta):
                kind = meta[0]
                kinds[kind] = kinds.get(kind, 0) + 1
                if kind == "ret":
                    r = meta[1]
                    rids.append(r.request_id)
                    rows.append(self._attach(r, track, now, end,
                                             "retrieval_compute"))
                elif kind == "shard":
                    gather = meta[1]
                    r = gather.req
                    rids.append(r.request_id)
                    rows.append(self._attach(r, track, now, end,
                                             "retrieval_compute"))
                    self._gather_parts.setdefault(id(gather), []).append(
                        (track, end))
                elif kind == "stage":
                    r = meta[1]
                    rids.append(r.request_id)
                    rows.append(self._attach(r, track, now, end,
                                             "stage_compute"))
                # "spec" warmups are background work: on the span, not
                # attributable to any request's latency
        for task, _fn in job.get("tasks", ()):
            kinds[task.kind] = kinds.get(task.kind, 0) + 1
            rids.append(task.req.request_id)
            rows.append(self._attach(task.req, track, now, end,
                                     "stage_compute"))
        name = "+".join(f"{k}x{n}" for k, n in sorted(kinds.items())) or "ret"
        if hedge:
            name = f"hedge {name}"
        span = self._span(track, name, now, end - now,
                          "hedge" if hedge else "ret",
                          {"requests": sorted(set(rids)), "worker": int(wid),
                           "hedge": bool(hedge)})
        self._job_spans[id(job)] = span
        self._job_rows[id(job)] = rows

    @handoff("scheduler")
    def ret_job_lost(self, job, now: float) -> None:
        """The worker died mid-job: its results are fenced, so the time the
        involved requests spent on it was recovery, not service."""
        span = self._job_spans.get(id(job))
        if span is not None:
            span["args"] = dict(span["args"], lost=True)
            span["name"] = f"lost {span['name']}"
            span["cat"] = "lost"
        for row in self._job_rows.get(id(job), ()):
            row[2] = "fault_recovery"

    @handoff("scheduler")
    def hedge_link(self, job, hjob, now: float) -> None:
        src = self._job_spans.get(id(job))
        dst = self._job_spans.get(id(hjob))
        if src is None or dst is None:
            return
        self._flow("hedge", (src["track"], dst["ts"]),
                   (dst["track"], dst["ts"]), name="hedge")

    @handoff("scheduler")
    def gather_merge(self, gather, now: float) -> None:
        rid = gather.req.request_id
        parts = self._gather_parts.pop(id(gather), [])
        self._instant(QUEUE_TRACK, f"merge r{rid}", now, "gather",
                      {"request": rid, "parts": len(parts),
                       "clusters": len(gather.clusters)})
        for p in parts:
            self._flow("gather", p, (QUEUE_TRACK, now), name=f"r{rid}")
        e = self.requests.get(rid)
        if e is not None:
            e.intervals.append([float(now), float(now), "merge"])

    @handoff("scheduler")
    def fanout(self, leader, sub, now: float, kind: str) -> None:
        e = self._req(leader)
        src = e.frontier or (QUEUE_TRACK, float(now))
        self._instant(QUEUE_TRACK, f"fused r{sub.request_id}", now,
                      "fusion", {"request": sub.request_id,
                                 "leader": leader.request_id, "kind": kind})
        self._flow("fusion", src, (QUEUE_TRACK, float(now)),
                   name=f"r{leader.request_id}->r{sub.request_id}")

    @handoff("scheduler")
    def open_gap(self, req, now: float, component: str) -> None:
        """Start a wait gap (``retry_hedge_failover`` backoff or
        ``fault_recovery`` after a worker death); closed by the request's
        next dispatched span, or at finish."""
        if req is None or req.finished:
            return
        e = self._req(req)
        if e.gap is None:
            e.gap = (float(now), component)

    @handoff("scheduler")
    def failover(self, req, wid: int, now: float) -> None:
        self._instant(QUEUE_TRACK, f"failover r{req.request_id}->w{wid}",
                      now, "failover",
                      {"request": req.request_id, "worker": int(wid)})

    @handoff("scheduler")
    def degraded(self, req, now: float) -> None:
        self._instant(QUEUE_TRACK, f"degraded r{req.request_id}", now,
                      "degraded", {"request": req.request_id})

    @handoff("scheduler")
    def worker_transition(self, wid: int, old: str, new: str,
                          now: float) -> None:
        self._instant(ret_track(wid), f"w{wid} {old}->{new}", now,
                      "lifecycle", {"worker": int(wid), "from": old,
                                    "to": new})

    # -------------------------------------------------------------- export
    def to_chrome(self) -> dict:
        """Render as Chrome trace-event JSON (Perfetto-compatible)."""
        tracks = {QUEUE_TRACK, GEN_TRACK}
        for s in self.spans:
            tracks.add(s["track"])
        for i in self.instants:
            tracks.add(i["track"])
        for f in self.flows:
            tracks.add(f["src"][0])
            tracks.add(f["dst"][0])
        ev: list[dict] = [{
            "ph": "M", "pid": _PID, "tid": 0, "ts": 0.0,
            "name": "process_name", "args": {"name": "hedrarag-server"},
        }]
        for t in sorted(tracks, key=_tid):
            ev.append({"ph": "M", "pid": _PID, "tid": _tid(t), "ts": 0.0,
                       "name": "thread_name",
                       "args": {"name": _track_name(t)}})
        body: list[dict] = []
        for s in self.spans:
            body.append({"ph": "X", "pid": _PID, "tid": _tid(s["track"]),
                         "ts": s["ts"], "dur": max(s["dur"], 0.0),
                         "name": s["name"], "cat": s["cat"],
                         "args": s["args"]})
        for i in self.instants:
            body.append({"ph": "i", "s": "t", "pid": _PID,
                         "tid": _tid(i["track"]), "ts": i["ts"],
                         "name": i["name"], "cat": i["cat"],
                         "args": i["args"]})
        for f in self.flows:
            base = {"name": f["name"], "cat": f["cat"], "id": f["fid"],
                    "pid": _PID}
            body.append(dict(base, ph="s", tid=_tid(f["src"][0]),
                             ts=f["src"][1]))
            body.append(dict(base, ph="f", bp="e", tid=_tid(f["dst"][0]),
                             ts=f["dst"][1]))
        # stable global time sort keeps every per-track ts sequence monotone
        body.sort(key=lambda e: e["ts"])
        return {
            "traceEvents": ev + body,
            "displayTimeUnit": "ms",
            "otherData": {
                "generator": "repro.obs.trace",
                "n_requests": len(self.requests),
                "clock": "virtual-us",
            },
        }

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1)


# ---------------------------------------------------------------------------
# Structural validation (used by tests, the CLI, and CI)
# ---------------------------------------------------------------------------

_ALLOWED_PH = {"M", "X", "i", "B", "E", "s", "f", "t"}


def validate_trace(trace: dict) -> list[str]:
    """Structural validity of a Chrome trace-event JSON object.  Returns a
    list of human-readable problems — empty means valid:

    * top-level ``traceEvents`` list, every event carrying ``ph`` / ``pid``
      / ``tid`` / ``ts`` / ``name``;
    * only known phase codes, ``X`` events with non-negative ``dur``;
    * per-(pid, tid) timestamps non-decreasing in array order;
    * ``B``/``E`` duration events balanced per track;
    * every flow id has both a start (``s``) and a finish (``f``) event.
    """
    problems: list[str] = []
    evs = trace.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    last_ts: dict[tuple, float] = {}
    be_stack: dict[tuple, int] = {}
    flow_s: dict = {}
    flow_f: dict = {}
    for n, e in enumerate(evs):
        for key in ("ph", "pid", "tid", "ts", "name"):
            if key not in e:
                problems.append(f"event {n}: missing {key!r}")
        ph = e.get("ph")
        if ph not in _ALLOWED_PH:
            problems.append(f"event {n}: unknown phase {ph!r}")
            continue
        track = (e.get("pid"), e.get("tid"))
        ts = float(e.get("ts", 0.0))
        if ph != "M":
            if ts < last_ts.get(track, float("-inf")):
                problems.append(
                    f"event {n}: ts {ts} decreases on track {track}")
            last_ts[track] = ts
        if ph == "X" and float(e.get("dur", -1.0)) < 0.0:
            problems.append(f"event {n}: X event with negative/missing dur")
        elif ph == "B":
            be_stack[track] = be_stack.get(track, 0) + 1
        elif ph == "E":
            be_stack[track] = be_stack.get(track, 0) - 1
            if be_stack[track] < 0:
                problems.append(f"event {n}: E without matching B on {track}")
        elif ph == "s":
            flow_s.setdefault(e.get("id"), 0)
            flow_s[e.get("id")] += 1
        elif ph in ("f", "t"):
            flow_f.setdefault(e.get("id"), 0)
            flow_f[e.get("id")] += 1
    for track, depth in sorted(be_stack.items()):
        if depth != 0:
            problems.append(f"unbalanced B/E on track {track}: depth {depth}")
    for fid in sorted(set(flow_s) - set(flow_f), key=repr):
        problems.append(f"flow id {fid!r} has a start but no finish")
    for fid in sorted(set(flow_f) - set(flow_s), key=repr):
        problems.append(f"flow id {fid!r} has a finish but no start")
    return problems


def request_ids_in_trace(trace: dict) -> set:
    """Every request id referenced by any event's args (``request`` scalar
    or ``requests`` list) — the join key against the request journal."""
    out: set = set()
    for e in trace.get("traceEvents", ()):
        args = e.get("args") or {}
        if "request" in args:
            out.add(int(args["request"]))
        for rid in args.get("requests", ()):
            out.add(int(rid))
    return out
