"""End-to-end observability for the serving runtime.

Three layers, all default-off and purely passive (enabling them never
changes a scheduling decision, an RNG draw, or a per-request event trace):

* ``obs.trace`` — a span tracer that turns the scheduler's dispatched jobs
  and per-request ``(t, event, payload)`` tuples into a Chrome
  trace-event / Perfetto JSON timeline: one track per resource (gen
  engine, each retrieval worker, the admission queue) with flow events for
  sub-stage dependencies, hedge duplicates, shard scatter/gather fan-out,
  dedup leader→follower fusion, and failover re-dispatch.
* ``obs.registry`` — a labeled metrics registry (counters / gauges /
  histograms with ``worker`` / ``stage_kind`` / ``workflow`` /
  ``slo_class`` labels) layered around the load-bearing ``Metrics``
  dataclass, plus a virtual-clock sampler for queue depth, per-worker
  utilization, and lifecycle states; rendered as a Prometheus-style text
  snapshot.
* ``obs.attribution`` — a latency attribution / critical-path analyzer
  that decomposes each finished request into queueing, retrieval compute,
  generation compute, stage compute, merge, retry/hedge/failover overhead,
  and fault-recovery time — components sum to the measured latency by
  construction.

Enable through the scheduler knobs (``tracing=True`` / ``telemetry=True``)
and read through ``Server.export_trace()`` / ``Server.metrics_snapshot()``
/ ``Server.attribution_report()``.
"""
from repro.obs.attribution import (  # noqa: F401
    ATTRIBUTION_COMPONENTS,
    attribute_request,
    attribution_report,
)
from repro.obs.registry import MetricsRegistry, TelemetrySampler  # noqa: F401
from repro.obs.trace import (  # noqa: F401
    TraceRecorder,
    request_ids_in_trace,
    validate_trace,
)
