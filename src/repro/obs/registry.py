"""Labeled metrics registry + virtual-clock telemetry sampler.

The registry is a small Prometheus-flavoured metric store (counters,
gauges, histograms, each with declared label names) layered *around* the
scheduler's load-bearing ``Metrics`` dataclass — the dataclass stays the
single source of truth for scheduling-side counters; the registry is a
read-only projection of it plus the periodic samples the dataclass cannot
hold (queue depth, per-worker utilization, lifecycle state populations,
pending-heap size over virtual time).

* :class:`MetricsRegistry` — ``counter()`` / ``gauge()`` / ``histogram()``
  families with ``.labels(**kw)`` children, rendered either as a
  Prometheus text-exposition snapshot (``render()``) or a JSON-safe
  structured snapshot with a schema-version field (``snapshot()``).
* :class:`TelemetrySampler` — attached by ``SchedulerConfig.telemetry``;
  ``maybe_sample()`` fires at ``telemetry_interval_us`` boundaries of the
  *virtual* clock inside the scheduler cycle, and per-event hooks
  (``on_finish`` / ``on_ret_job`` / ``on_gen_job``) feed the labeled
  families.  ``finalize()`` folds the ``Metrics`` dataclass counters in at
  the end of a run.

Everything here is passive: sampling reads scheduler state, never mutates
it, and draws no randomness — telemetry-on runs are bit-identical to
telemetry-off runs.
"""
from __future__ import annotations

from typing import Optional

from repro.core.ownership import handoff, owned_by

SNAPSHOT_SCHEMA_VERSION = 1

# log-spaced latency buckets in virtual microseconds: 1 ms .. 10 s
DEFAULT_BUCKETS_US = (
    1e3, 2.5e3, 5e3, 1e4, 2.5e4, 5e4, 1e5, 2.5e5, 5e5,
    1e6, 2.5e6, 5e6, 1e7,
)


def _escape(v) -> str:
    return (str(v).replace("\\", r"\\").replace('"', r"\"")
            .replace("\n", r"\n"))


def _fmt(value: float) -> str:
    f = float(value)
    if f != f:
        return "NaN"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _label_str(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class _Family:
    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: tuple):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self.children: dict[tuple, object] = {}

    def labels(self, **kw):
        if set(kw) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(sorted(kw))}")
        key = tuple(str(kw[k]) for k in self.labelnames)
        child = self.children.get(key)
        if child is None:
            child = self._make_child()
            self.children[key] = child
        return child

    def _default_child(self):
        """The no-label singleton child (valid only when labelnames=())."""
        return self.labels()

    def _make_child(self):  # pragma: no cover - abstract
        raise NotImplementedError

    def _sorted_children(self):
        return sorted(self.children.items())

    def _labels_of(self, key: tuple) -> dict:
        return dict(zip(self.labelnames, key))


class _CounterChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        self.value += float(amount)


class Counter(_Family):
    kind = "counter"

    def _make_child(self):
        return _CounterChild()

    def inc(self, amount: float = 1.0, **labels) -> None:
        self.labels(**labels).inc(amount)

    def render(self) -> list[str]:
        return [f"{self.name}{_label_str(self._labels_of(k))} "
                f"{_fmt(c.value)}"
                for k, c in self._sorted_children()]

    def sample_dicts(self) -> list[dict]:
        return [{"labels": self._labels_of(k), "value": c.value}
                for k, c in self._sorted_children()]


class _GaugeChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += float(amount)


class Gauge(_Family):
    kind = "gauge"

    def _make_child(self):
        return _GaugeChild()

    def set(self, value: float, **labels) -> None:
        self.labels(**labels).set(value)

    def render(self) -> list[str]:
        return [f"{self.name}{_label_str(self._labels_of(k))} "
                f"{_fmt(c.value)}"
                for k, c in self._sorted_children()]

    def sample_dicts(self) -> list[dict]:
        return [{"labels": self._labels_of(k), "value": c.value}
                for k, c in self._sorted_children()]


class _HistogramChild:
    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: tuple):
        self.buckets = buckets
        self.counts = [0] * len(buckets)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        self.sum += v
        self.count += 1
        for i, le in enumerate(self.buckets):
            if v <= le:
                self.counts[i] += 1

    def cumulative(self) -> list[int]:
        return list(self.counts)


class Histogram(_Family):
    kind = "histogram"

    def __init__(self, name: str, help: str, labelnames: tuple,
                 buckets: tuple = DEFAULT_BUCKETS_US):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(float(b) for b in buckets))

    def _make_child(self):
        return _HistogramChild(self.buckets)

    def observe(self, value: float, **labels) -> None:
        self.labels(**labels).observe(value)

    def render(self) -> list[str]:
        out = []
        for k, c in self._sorted_children():
            base = self._labels_of(k)
            for le, n in zip(self.buckets, c.cumulative()):
                out.append(
                    f"{self.name}_bucket"
                    f"{_label_str(dict(base, le=_fmt(le)))} {n}")
            out.append(f"{self.name}_bucket"
                       f"{_label_str(dict(base, le='+Inf'))} {c.count}")
            out.append(f"{self.name}_sum{_label_str(base)} {_fmt(c.sum)}")
            out.append(f"{self.name}_count{_label_str(base)} {c.count}")
        return out

    def sample_dicts(self) -> list[dict]:
        return [{"labels": self._labels_of(k),
                 "buckets": {_fmt(le): n for le, n in
                             zip(self.buckets, c.cumulative())},
                 "sum": c.sum, "count": c.count}
                for k, c in self._sorted_children()]


class MetricsRegistry:
    """Declared metric families addressed by name; one instance per server."""

    def __init__(self):
        self._families: dict[str, _Family] = {}

    def _register(self, fam: _Family) -> _Family:
        have = self._families.get(fam.name)
        if have is not None:
            if type(have) is not type(fam):
                raise ValueError(
                    f"metric {fam.name!r} already registered as {have.kind}")
            return have
        self._families[fam.name] = fam
        return fam

    def counter(self, name: str, help: str = "",
                labelnames: tuple = ()) -> Counter:
        return self._register(Counter(name, help, labelnames))

    def gauge(self, name: str, help: str = "",
              labelnames: tuple = ()) -> Gauge:
        return self._register(Gauge(name, help, labelnames))

    def histogram(self, name: str, help: str = "", labelnames: tuple = (),
                  buckets: tuple = DEFAULT_BUCKETS_US) -> Histogram:
        return self._register(Histogram(name, help, labelnames, buckets))

    def render(self) -> str:
        """Prometheus text exposition format (sorted by metric name)."""
        lines: list[str] = []
        for name in sorted(self._families):
            fam = self._families[name]
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            lines.extend(fam.render())
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """JSON-safe structured snapshot (stable key order)."""
        return {
            "schema_version": SNAPSHOT_SCHEMA_VERSION,
            "metrics": {
                name: {
                    "type": fam.kind,
                    "help": fam.help,
                    "labelnames": list(fam.labelnames),
                    "samples": fam.sample_dicts(),
                }
                for name, fam in sorted(self._families.items())
            },
        }


def slo_class_of(slo_us) -> str:
    """Stable label value for a request's SLO tier (the workload layer keys
    tiers by their microsecond budget, so the budget *is* the class)."""
    if not slo_us or float(slo_us) <= 0 or float(slo_us) == float("inf"):
        return "none"
    return f"{int(float(slo_us))}us"


@owned_by("obs")
class TelemetrySampler:
    """Virtual-clock sampler driven from the scheduler cycle.

    ``maybe_sample(sched, now)`` records one sample row per elapsed
    ``interval_us`` boundary (queue depth, active count, per-worker
    utilization, pending-heap size, lifecycle state populations, gen
    utilization) and mirrors the latest values into registry gauges;
    ``on_*`` hooks feed labeled counters/histograms as events happen.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 interval_us: float = 50_000.0):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.interval_us = max(float(interval_us), 1.0)
        self.samples: list[dict] = []
        self._next_sample_us = 0.0
        r = self.registry
        self.m_pending = r.gauge(
            "repro_pending_depth",
            "requests queued for admission (pending heap size)")
        self.m_active = r.gauge(
            "repro_active_requests", "requests admitted and in flight")
        self.m_worker_util = r.gauge(
            "repro_worker_utilization",
            "per-worker completed-busy fraction of virtual time",
            labelnames=("worker",))
        self.m_gen_util = r.gauge(
            "repro_gen_utilization",
            "gen-engine busy fraction of virtual time")
        self.m_lifecycle = r.gauge(
            "repro_workers_by_state",
            "retrieval workers per lifecycle state",
            labelnames=("state",))
        self.m_samples = r.counter(
            "repro_telemetry_samples_total", "telemetry sample rows taken")
        self.m_finished = r.counter(
            "repro_requests_finished_total",
            "finished requests by workflow and SLO tier",
            labelnames=("workflow", "slo_class"))
        self.m_latency = r.histogram(
            "repro_request_latency_us",
            "end-to-end request latency (virtual us)",
            labelnames=("workflow", "slo_class"))
        self.m_shed = r.counter(
            "repro_requests_shed_total", "requests shed at admission",
            labelnames=("reason",))
        self.m_ret_jobs = r.counter(
            "repro_ret_jobs_total",
            "retrieval-side dispatches by worker and stage kind",
            labelnames=("worker", "stage_kind"))
        self.m_gen_jobs = r.counter(
            "repro_gen_jobs_total", "generation batches dispatched")
        self.m_sched = r.gauge(
            "repro_scheduler_counter",
            "Metrics dataclass counters folded at end of run",
            labelnames=("name",))
        # wall-clock ingress track (serving/ingress.py): the loop hands the
        # wall/virtual clock values in as arguments — obs never reads time
        self.wall_samples: list[dict] = []
        self.m_ingress_rows = r.counter(
            "repro_ingress_rows_total",
            "ingress trace rows applied by kind",
            labelnames=("kind",))
        self.m_ingress_depth = r.gauge(
            "repro_ingress_queue_depth",
            "producer->scheduler queue occupancy at last wall sample")
        self.m_clock_drift = r.gauge(
            "repro_ingress_clock_drift_us",
            "wall clock minus event clock at last wall sample (virtual us)")

    # ----------------------------------------------------------- event hooks
    @handoff("scheduler")
    def on_finish(self, req, now: float) -> None:
        wf = req.graph.name
        sc = slo_class_of(req.slo_us)
        self.m_finished.inc(workflow=wf, slo_class=sc)
        self.m_latency.observe(float(now) - float(req.arrival_us),
                               workflow=wf, slo_class=sc)

    @handoff("scheduler")
    def on_shed(self, req, reason: str) -> None:
        self.m_shed.inc(reason=str(reason))

    @handoff("scheduler")
    def on_ret_job(self, job, wid: int) -> None:
        kinds: dict[str, int] = {}
        plan = job.get("plan")
        if plan is not None:
            for meta in plan.group_meta:
                kinds[meta[0]] = kinds.get(meta[0], 0) + 1
        for task, _fn in job.get("tasks", ()):
            kinds[task.kind] = kinds.get(task.kind, 0) + 1
        for kind, n in kinds.items():
            self.m_ret_jobs.inc(n, worker=str(int(wid)), stage_kind=kind)

    @handoff("scheduler")
    def on_gen_job(self, job) -> None:
        self.m_gen_jobs.inc()

    @handoff("server")
    def on_ingress_row(self, kind: str) -> None:
        """One ingress trace row applied (arrival/heartbeat/readmit/tick)."""
        self.m_ingress_rows.inc(kind=str(kind))

    @handoff("server")
    def on_wall_sample(self, *, wall_us: float, virtual_us: float,
                       queue_depth: int, parked: int) -> None:
        """Periodic wall-clock tap from the ingress loop.  Passive and
        unrecorded: replayed runs simply have an empty wall track; the
        fingerprint contract is unaffected."""
        self.m_ingress_depth.set(float(queue_depth))
        self.m_clock_drift.set(float(wall_us) - float(virtual_us))
        self.wall_samples.append({
            "wall_us": float(wall_us),
            "virtual_us": float(virtual_us),
            "drift_us": float(wall_us) - float(virtual_us),
            "queue_depth": int(queue_depth),
            "parked": int(parked),
        })

    # ------------------------------------------------------------- sampling
    @handoff("scheduler")
    def maybe_sample(self, sched, now: float) -> None:
        if now < self._next_sample_us:
            return
        self._sample(sched, now)
        # skip ahead past any idle gap: one sample per boundary crossed
        k = int((now - self._next_sample_us) // self.interval_us) + 1
        self._next_sample_us += k * self.interval_us

    def _sample(self, sched, now: float) -> None:
        t = max(float(now), 1e-9)
        pending = len(sched._pending)
        active = len(sched.active)
        util = sched.dispatcher.utilization(t)
        states = sched.lifecycle.state_counts()
        gen_util = sched.metrics.gen_busy_us / t
        self.m_pending.set(pending)
        self.m_active.set(active)
        self.m_gen_util.set(gen_util)
        for w, u in enumerate(util):
            self.m_worker_util.set(u, worker=str(w))
        for state, n in states.items():
            self.m_lifecycle.set(n, state=state)
        self.m_samples.inc()
        self.samples.append({
            "t_us": float(now),
            "pending": pending,
            "active": active,
            "gen_util": gen_util,
            "worker_util": [float(u) for u in util],
            "lifecycle": states,
        })

    @handoff("scheduler")
    def finalize(self, sched, now: float) -> None:
        """End-of-run fold: one last sample plus the ``Metrics`` dataclass
        scalar counters projected into ``repro_scheduler_counter``."""
        self._sample(sched, now)
        m = sched.metrics
        for name in sorted(vars(m)):
            v = getattr(m, name)
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            self.m_sched.set(float(v), name=name)

    def snapshot(self) -> dict:
        snap = self.registry.snapshot()
        snap["interval_us"] = self.interval_us
        snap["timeline"] = list(self.samples)
        snap["wall_timeline"] = list(self.wall_samples)
        return snap
